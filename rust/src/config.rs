//! Configuration for the serving stack: model size, SpecPV cache geometry,
//! engine selection, offload simulation. Loadable from a simple `key=value`
//! file with CLI overrides (no TOML crate offline; the format is a strict
//! subset of TOML).

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

/// Retrieval score reduction over the verification step's queries
/// (paper Eq. 3 / Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reduction {
    Mean,
    Max,
    Last,
}

impl Reduction {
    /// Row index within the stacked `[mean, max, last]` score output of
    /// the `score_*` executables.
    pub fn row(self) -> usize {
        match self {
            Reduction::Mean => 0,
            Reduction::Max => 1,
            Reduction::Last => 2,
        }
    }
}

impl std::str::FromStr for Reduction {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "mean" => Ok(Reduction::Mean),
            "max" => Ok(Reduction::Max),
            "last" => Ok(Reduction::Last),
            _ => bail!("unknown reduction '{s}' (mean|max|last)"),
        }
    }
}

impl fmt::Display for Reduction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Reduction::Mean => "mean",
            Reduction::Max => "max",
            Reduction::Last => "last",
        })
    }
}

/// Decoding engine selection (paper §4.1 baselines + SpecPV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum EngineKind {
    /// standard autoregressive decoding (the speedup denominator)
    Autoregressive,
    /// EAGLE3-YARN: tree speculation, full verification every step
    SpecFull,
    /// SpecPV: partial verification + periodic refresh (the paper)
    SpecPv,
    /// TriForce-like: independent tiny draft LM, full verification
    TriForce,
    /// TokenSwift-like: Medusa heads, full verification
    TokenSwift,
}

impl std::str::FromStr for EngineKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "ar" | "autoregressive" => Ok(EngineKind::Autoregressive),
            "spec_full" | "eagle3" => Ok(EngineKind::SpecFull),
            "spec_pv" | "specpv" => Ok(EngineKind::SpecPv),
            "triforce" => Ok(EngineKind::TriForce),
            "tokenswift" => Ok(EngineKind::TokenSwift),
            _ => bail!("unknown engine '{s}'"),
        }
    }
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            EngineKind::Autoregressive => "ar",
            EngineKind::SpecFull => "spec_full",
            EngineKind::SpecPv => "spec_pv",
            EngineKind::TriForce => "triforce",
            EngineKind::TokenSwift => "tokenswift",
        })
    }
}

/// Which device backend executes the kernel ops (`backend::Backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// pjrt when `artifacts/manifest.json` exists, reference otherwise
    Auto,
    /// PJRT CPU client over the AOT artifacts (`backend::pjrt`)
    Pjrt,
    /// pure-Rust host executor, no artifacts (`backend::reference`)
    Reference,
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(BackendKind::Auto),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            "reference" | "ref" | "host" => Ok(BackendKind::Reference),
            _ => bail!("unknown backend '{s}' (auto|pjrt|reference)"),
        }
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            BackendKind::Auto => "auto",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Reference => "reference",
        })
    }
}

/// Storage precision for cold/swapped KV pages (`kv_quant`). Resident
/// pages are always exact f32; int8 applies only to pages demoted by
/// `KvPool::park_cold` and is tolerance-bounded (DESIGN.md §13).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvQuant {
    #[default]
    None,
    Int8,
}

impl std::str::FromStr for KvQuant {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "none" | "f32" => Ok(KvQuant::None),
            "int8" => Ok(KvQuant::Int8),
            _ => bail!("unknown kv_quant '{s}' (none|int8)"),
        }
    }
}

impl fmt::Display for KvQuant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            KvQuant::None => "none",
            KvQuant::Int8 => "int8",
        })
    }
}

/// Fsync policy for the write-ahead request journal (`journal_fsync`,
/// DESIGN.md §17). `Always` syncs every appended record (loses nothing
/// on `kill -9`, one fsync per record), `IntervalMs(n)` syncs at most
/// every `n` milliseconds (bounded loss window, amortized cost),
/// `Never` leaves flushing to the OS (crash may lose the journal tail;
/// a clean shutdown still syncs).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum JournalFsync {
    #[default]
    Always,
    IntervalMs(u64),
    Never,
}

impl std::str::FromStr for JournalFsync {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "always" => Ok(JournalFsync::Always),
            "never" => Ok(JournalFsync::Never),
            _ => match s.strip_prefix("interval_ms:") {
                Some(ms) => Ok(JournalFsync::IntervalMs(ms.parse().map_err(|_| {
                    anyhow::anyhow!("bad interval in journal_fsync '{s}'")
                })?)),
                None => bail!("unknown journal_fsync '{s}' (always|interval_ms:N|never)"),
            },
        }
    }
}

impl fmt::Display for JournalFsync {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JournalFsync::Always => f.write_str("always"),
            JournalFsync::IntervalMs(ms) => write!(f, "interval_ms:{ms}"),
            JournalFsync::Never => f.write_str("never"),
        }
    }
}

/// SpecPV partial-cache geometry (paper §3.2). All unit = tokens unless
/// noted. `retrieval_budget` is the headline "SpecPV-xK" knob.
#[derive(Debug, Clone)]
pub struct SpecPvConfig {
    /// retrieval-segment budget in tokens (256 | 512 | 1024 here ≙ the
    /// paper's 2K | 4K | 8K at its 10× context scale)
    pub retrieval_budget: usize,
    /// attention-sink blocks always kept (tokens = blocks × block_size)
    pub sink_blocks: usize,
    /// local-window blocks always kept
    pub local_blocks: usize,
    /// buffer capacity: partially-verified tokens held before a Refresh
    /// is forced (paper default: one verification step's tokens + 20)
    pub buffer_cap: usize,
    /// score reduction f (paper Eq. 3)
    pub reduction: Reduction,
}

impl Default for SpecPvConfig {
    fn default() -> Self {
        SpecPvConfig {
            retrieval_budget: 512,
            sink_blocks: 1,
            local_blocks: 2,
            buffer_cap: 16 + 20,
            reduction: Reduction::Mean,
        }
    }
}

impl SpecPvConfig {
    /// Partial bucket required: core tokens (sink+retrieval+local) plus
    /// buffer headroom, rounded up to the compiled partial buckets.
    pub fn core_tokens(&self, block: usize) -> usize {
        (self.sink_blocks + self.local_blocks) * block + self.retrieval_budget
    }
}

/// Speculation policy mode (`policy` key, DESIGN.md §16).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyMode {
    /// no policy layer at all: no per-session tracking, no counters
    Off,
    /// observe-only: acceptance/drift counters accrue (registry + admin
    /// metrics) but every speculation knob stays at its configured value
    #[default]
    Fixed,
    /// closed loop: draft depth follows acceptance feedback and SpecPV
    /// refreshes on the drift threshold (fixed cadence stays as the
    /// fallback ceiling)
    Adaptive,
}

impl std::str::FromStr for PolicyMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(PolicyMode::Off),
            "fixed" => Ok(PolicyMode::Fixed),
            "adaptive" => Ok(PolicyMode::Adaptive),
            _ => bail!("unknown policy '{s}' (off|fixed|adaptive)"),
        }
    }
}

impl fmt::Display for PolicyMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            PolicyMode::Off => "off",
            PolicyMode::Fixed => "fixed",
            PolicyMode::Adaptive => "adaptive",
        })
    }
}

/// Adaptive speculation policy knobs (DESIGN.md §16). The controller in
/// `crate::policy` is a pure function of the observed decode stream and
/// these bounds.
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    pub mode: PolicyMode,
    /// draft-depth bounds the controller never leaves
    pub draft_min: usize,
    pub draft_max: usize,
    /// EWMA smoothing for the per-round acceptance ratio, (0, 1]
    pub alpha: f64,
    /// acceptance EWMA at or above this grows the draft depth
    pub grow: f64,
    /// acceptance EWMA at or below this shrinks the draft depth (also
    /// the `engine=auto` probe's give-up-on-speculation threshold)
    pub shrink: f64,
    /// verify rounds between depth adjustments
    pub adjust_every: usize,
    /// accumulated acceptance-shortfall (partial rounds) that forces a
    /// SpecPV refresh ahead of the buffer-cap cadence
    pub drift_threshold: f64,
    /// observed verify rounds before the `engine=auto` acceptance probe
    /// may veto a speculative engine
    pub probe_rounds: usize,
    /// `engine=auto`: prompts shorter than this decode plain `ar`
    pub auto_short: usize,
    /// `engine=auto`: prompts at least this long go to `spec_pv`
    /// (between the two bounds: `triforce`)
    pub auto_long: usize,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            mode: PolicyMode::Fixed,
            draft_min: 1,
            draft_max: 6,
            alpha: 0.3,
            grow: 0.8,
            shrink: 0.35,
            adjust_every: 4,
            drift_threshold: 1.5,
            probe_rounds: 8,
            auto_short: 64,
            auto_long: 640,
        }
    }
}

/// Offload simulation (paper Fig. 4: RTX 4090 + PCIe KV offload).
#[derive(Debug, Clone)]
pub struct OffloadConfig {
    pub enabled: bool,
    /// effective host↔device bandwidth, GB/s (PCIe 4.0 x16 effective)
    pub pcie_gbps: f64,
    /// fraction of transfer hidden by per-layer prefetch overlap
    pub overlap: f64,
}

impl Default for OffloadConfig {
    fn default() -> Self {
        OffloadConfig { enabled: false, pcie_gbps: 12.0, overlap: 0.3 }
    }
}

/// Top-level serving configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub artifacts_dir: PathBuf,
    pub model_size: String,
    pub engine: EngineKind,
    /// `engine = auto`: pick the engine per request (prompt length +
    /// acceptance probe, DESIGN.md §16); `engine` stays as the fallback
    /// when the policy layer is off
    pub engine_auto: bool,
    /// device backend (auto: pjrt with artifacts, reference without)
    pub backend: BackendKind,
    /// adaptive speculation policy (DESIGN.md §16)
    pub policy: PolicyConfig,
    pub specpv: SpecPvConfig,
    pub offload: OffloadConfig,
    pub temperature: f32,
    pub max_new_tokens: usize,
    /// draft tree: children of the root level
    pub tree_top_k: usize,
    /// draft tree: expansion depth (levels after the root)
    pub tree_depth: usize,
    /// total tree nodes (≤ compiled TREE_T)
    pub tree_size: usize,
    /// TriForce chain draft length γ
    pub chain_gamma: usize,
    pub server_addr: String,
    /// continuous-batching width: concurrent live sessions the
    /// coordinator's round-robin scheduler interleaves
    pub max_active: usize,
    /// admission: longest accepted prompt, tokens
    pub max_prompt: usize,
    /// admission: deepest request queue before submits are rejected
    pub max_queue: usize,
    /// KV state manager: byte budget for resident session state; the
    /// coordinator gates admission on it and swaps the lowest-priority
    /// session out under pressure (0 = unlimited, count-only admission)
    pub kv_budget_bytes: usize,
    /// KV state manager: byte budget of the prompt-prefix snapshot cache
    /// consulted by prefill (0 = disabled)
    pub prefix_cache_bytes: usize,
    /// paged KV pool: fixed page size in bytes (positive multiple of 4)
    pub kv_page_bytes: usize,
    /// paged KV pool: spill directory for the disk tier ("" = disabled)
    pub kv_swap_dir: String,
    /// paged KV pool: storage precision for cold/swapped pages
    pub kv_quant: KvQuant,
    /// kernel thread-pool width for the reference backend, mirroring the
    /// `SPECPV_THREADS` env override (0 = env/auto default); echoed in
    /// `Registry::summary`
    pub threads: usize,
    /// serve: worker shards, each owning a private `Coordinator` +
    /// `Backend` + KV pool on its own thread (1 = today's single-worker
    /// behavior, byte-identical outputs)
    pub shards: usize,
    /// serve: router spill factor — a session leaves its prefix-affinity
    /// home shard only when `home_load + 1 > route_imbalance *
    /// (min_load + 1)` (≥ 1.0; larger keeps affinity stickier)
    pub route_imbalance: f64,
    /// serve: checkpoint a session's paged-KV state to the front end
    /// every N scheduler steps so shard failover can resume instead of
    /// regenerating (0 = off; failover regenerates from the prompt)
    pub checkpoint_every_steps: usize,
    /// serve: per-shard outstanding-request bound before the front end
    /// sheds new work with `{"error":"overloaded","retry_after_ms":…}`
    /// (0 = unlimited, today's silent-queueing behavior)
    pub shard_queue: usize,
    /// serve: supervised shard restarts before the shard degrades to an
    /// error-answering stub
    pub max_restarts: usize,
    /// serve: a supervised shard busy for longer than this without a
    /// heartbeat is declared wedged and failed over (0 = off)
    pub shard_heartbeat_ms: u64,
    /// fault injection: failpoint spec string (see
    /// `util::failpoint::FaultSpec`; "" = all off)
    pub faults: String,
    /// durability (DESIGN.md §17): directory for the write-ahead request
    /// journal + durable checkpoint store ("" = off). With it set, a
    /// cold restart replays unfinished sessions and `generate_retry`
    /// reconnects clients to exactly the missing output suffix.
    pub journal_dir: String,
    /// durability: journal fsync policy (always | interval_ms:N | never)
    pub journal_fsync: JournalFsync,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: PathBuf::from("artifacts"),
            model_size: "s".into(),
            engine: EngineKind::SpecPv,
            engine_auto: false,
            backend: BackendKind::Auto,
            policy: PolicyConfig::default(),
            specpv: SpecPvConfig::default(),
            offload: OffloadConfig::default(),
            temperature: 0.0,
            max_new_tokens: 256,
            tree_top_k: 4,
            tree_depth: 3,
            tree_size: 16,
            chain_gamma: 4,
            server_addr: "127.0.0.1:7799".into(),
            max_active: 4,
            max_prompt: 7 * 1024,
            max_queue: 256,
            kv_budget_bytes: 0,
            prefix_cache_bytes: 16 << 20,
            kv_page_bytes: 64 << 10,
            kv_swap_dir: String::new(),
            kv_quant: KvQuant::None,
            threads: 0,
            shards: 1,
            route_imbalance: 2.0,
            checkpoint_every_steps: 0,
            shard_queue: 0,
            max_restarts: 3,
            shard_heartbeat_ms: 0,
            faults: String::new(),
            journal_dir: String::new(),
            journal_fsync: JournalFsync::Always,
        }
    }
}

impl Config {
    /// Parse a `key = value` config file (strict TOML subset: no sections,
    /// `#` comments, unquoted or double-quoted scalars).
    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config {path:?}: {e}"))?;
        let mut kv = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key=value", lineno + 1))?;
            kv.insert(
                k.trim().to_string(),
                v.trim().trim_matches('"').to_string(),
            );
        }
        let mut cfg = Config::default();
        cfg.apply_overrides(&kv)?;
        Ok(cfg)
    }

    /// Apply `key=value` overrides (also used for CLI `--set key=value`).
    /// Every key is resolved through [`options`], the same table that
    /// generates the CLI flag parser — a key registers in exactly one
    /// place.
    pub fn apply_overrides(&mut self, kv: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in kv {
            let def = options()
                .iter()
                .find(|d| d.key == k.as_str())
                .ok_or_else(|| anyhow!("unknown config key '{k}'"))?;
            def.apply(self, v)?;
        }
        Ok(())
    }

    /// Spill directory for the paged-pool disk tier, if configured.
    pub fn swap_dir(&self) -> Option<PathBuf> {
        if self.kv_swap_dir.is_empty() {
            None
        } else {
            Some(PathBuf::from(&self.kv_swap_dir))
        }
    }

    /// Durability root (journal + checkpoint store), if configured.
    pub fn journal_path(&self) -> Option<PathBuf> {
        if self.journal_dir.is_empty() {
            None
        } else {
            Some(PathBuf::from(&self.journal_dir))
        }
    }
}

/// One config key = one CLI flag, declared once. The config-file /
/// `--set` parser ([`Config::apply_overrides`]) and the flag parser
/// (`main::build_config`) both iterate this table, so adding a key here
/// registers it everywhere.
pub struct OptDef {
    /// config-file key; the canonical CLI flag is the same with `_`→`-`
    pub key: &'static str,
    /// extra CLI-only alias kept for compatibility (e.g. `--budget`)
    pub alias: Option<&'static str>,
    /// CLI: a bare `--flag` means `true` (config files still use `k = v`)
    pub switch: bool,
    pub help: &'static str,
    apply: fn(&mut Config, &str) -> Result<()>,
}

impl OptDef {
    /// Canonical CLI flag name (`kv_page_bytes` → `kv-page-bytes`).
    pub fn flag(&self) -> String {
        self.key.replace('_', "-")
    }

    /// Parse `v` into the config field this option owns.
    pub fn apply(&self, cfg: &mut Config, v: &str) -> Result<()> {
        (self.apply)(cfg, v).map_err(|e| anyhow!("config key '{}' = '{v}': {e}", self.key))
    }
}

macro_rules! opt {
    ($key:literal, $help:literal, $apply:expr) => {
        OptDef { key: $key, alias: None, switch: false, help: $help, apply: $apply }
    };
    ($key:literal as $alias:literal, $help:literal, $apply:expr) => {
        OptDef { key: $key, alias: Some($alias), switch: false, help: $help, apply: $apply }
    };
}

static OPTIONS: &[OptDef] = &[
    opt!("artifacts_dir" as "artifacts", "AOT artifact directory", |c, v| {
        c.artifacts_dir = PathBuf::from(v);
        Ok(())
    }),
    opt!("model_size" as "size", "model size key (s|m|l)", |c, v| {
        c.model_size = v.to_string();
        Ok(())
    }),
    opt!("engine", "decoding engine (ar|spec_full|spec_pv|triforce|tokenswift|auto)", |c, v| {
        if v == "auto" {
            c.engine_auto = true;
        } else {
            c.engine = v.parse()?;
            c.engine_auto = false;
        }
        Ok(())
    }),
    opt!("backend", "device backend (auto|pjrt|reference)", |c, v| {
        c.backend = v.parse()?;
        Ok(())
    }),
    opt!("retrieval_budget" as "budget", "SpecPV retrieval budget, tokens", |c, v| {
        c.specpv.retrieval_budget = v.parse()?;
        Ok(())
    }),
    opt!("sink_blocks", "SpecPV attention-sink blocks", |c, v| {
        c.specpv.sink_blocks = v.parse()?;
        Ok(())
    }),
    opt!("local_blocks", "SpecPV local-window blocks", |c, v| {
        c.specpv.local_blocks = v.parse()?;
        Ok(())
    }),
    opt!("buffer_cap", "SpecPV partial-verify buffer capacity, tokens", |c, v| {
        c.specpv.buffer_cap = v.parse()?;
        Ok(())
    }),
    opt!("reduction", "SpecPV score reduction (mean|max|last)", |c, v| {
        c.specpv.reduction = v.parse()?;
        Ok(())
    }),
    OptDef {
        key: "offload",
        alias: None,
        switch: true,
        help: "enable the PCIe KV-offload simulation",
        apply: |c, v| {
            c.offload.enabled = v.parse()?;
            Ok(())
        },
    },
    opt!("pcie_gbps", "offload sim: effective PCIe bandwidth, GB/s", |c, v| {
        c.offload.pcie_gbps = v.parse()?;
        Ok(())
    }),
    opt!("overlap", "offload sim: prefetch overlap fraction", |c, v| {
        c.offload.overlap = v.parse()?;
        Ok(())
    }),
    opt!("temperature", "sampling temperature (0 = greedy)", |c, v| {
        c.temperature = v.parse()?;
        Ok(())
    }),
    opt!("max_new_tokens" as "max-new", "generation length cap, tokens", |c, v| {
        c.max_new_tokens = v.parse()?;
        Ok(())
    }),
    opt!("tree_top_k", "draft tree: children of the root level", |c, v| {
        c.tree_top_k = v.parse()?;
        Ok(())
    }),
    opt!("tree_depth", "draft tree: expansion depth", |c, v| {
        c.tree_depth = v.parse()?;
        Ok(())
    }),
    opt!("tree_size", "draft tree: total nodes", |c, v| {
        c.tree_size = v.parse()?;
        Ok(())
    }),
    opt!("chain_gamma", "TriForce chain draft length", |c, v| {
        c.chain_gamma = v.parse()?;
        Ok(())
    }),
    opt!("server_addr" as "addr", "serve: listen address", |c, v| {
        c.server_addr = v.to_string();
        Ok(())
    }),
    opt!("max_active", "scheduler: concurrent live sessions", |c, v| {
        c.max_active = v.parse()?;
        Ok(())
    }),
    opt!("max_prompt", "admission: longest accepted prompt, tokens", |c, v| {
        c.max_prompt = v.parse()?;
        Ok(())
    }),
    opt!("max_queue", "admission: deepest request queue", |c, v| {
        c.max_queue = v.parse()?;
        Ok(())
    }),
    opt!("kv_budget_bytes", "admission: resident KV byte budget (0 = unlimited)", |c, v| {
        c.kv_budget_bytes = v.parse()?;
        Ok(())
    }),
    opt!("prefix_cache_bytes", "prompt-prefix cache byte budget (0 = off)", |c, v| {
        c.prefix_cache_bytes = v.parse()?;
        Ok(())
    }),
    opt!("kv_page_bytes", "paged KV pool: page size, bytes (multiple of 4)", |c, v| {
        let n: usize = v.parse()?;
        if n == 0 || n % 4 != 0 {
            bail!("must be a positive multiple of 4");
        }
        c.kv_page_bytes = n;
        Ok(())
    }),
    opt!("kv_swap_dir", "paged KV pool: disk-tier spill directory (\"\" = off)", |c, v| {
        c.kv_swap_dir = v.to_string();
        Ok(())
    }),
    opt!("kv_quant", "cold/swapped KV page precision (none|int8)", |c, v| {
        c.kv_quant = v.parse()?;
        Ok(())
    }),
    opt!("threads", "reference-backend kernel threads (0 = auto)", |c, v| {
        c.threads = v.parse()?;
        Ok(())
    }),
    opt!("shards", "serve: worker shards (1 = single-worker behavior)", |c, v| {
        let n: usize = v.parse()?;
        if n == 0 {
            bail!("must be at least 1");
        }
        c.shards = n;
        Ok(())
    }),
    opt!("route_imbalance", "serve: router spill factor (>= 1.0)", |c, v| {
        let f: f64 = v.parse()?;
        if f.is_nan() || f < 1.0 {
            bail!("must be at least 1.0");
        }
        c.route_imbalance = f;
        Ok(())
    }),
    opt!("checkpoint_every_steps", "serve: failover checkpoint cadence, steps (0 = off)", |c, v| {
        c.checkpoint_every_steps = v.parse()?;
        Ok(())
    }),
    opt!("shard_queue", "serve: per-shard depth before shedding (0 = unlimited)", |c, v| {
        c.shard_queue = v.parse()?;
        Ok(())
    }),
    opt!("max_restarts", "serve: supervised shard restarts before giving up", |c, v| {
        c.max_restarts = v.parse()?;
        Ok(())
    }),
    opt!("shard_heartbeat_ms", "serve: busy-shard wedge timeout, ms (0 = off)", |c, v| {
        c.shard_heartbeat_ms = v.parse()?;
        Ok(())
    }),
    opt!("journal_dir", "durability: write-ahead journal + checkpoint dir (\"\" = off)", |c, v| {
        c.journal_dir = v.to_string();
        Ok(())
    }),
    opt!("journal_fsync", "durability: journal fsync policy (always|interval_ms:N|never)", |c, v| {
        c.journal_fsync = v.parse()?;
        Ok(())
    }),
    opt!("policy", "speculation policy (off|fixed|adaptive)", |c, v| {
        c.policy.mode = v.parse()?;
        Ok(())
    }),
    opt!("draft_min", "policy: smallest adaptive draft depth (>= 1)", |c, v| {
        let n: usize = v.parse()?;
        if n == 0 {
            bail!("must be at least 1");
        }
        c.policy.draft_min = n;
        Ok(())
    }),
    opt!("draft_max", "policy: largest adaptive draft depth", |c, v| {
        let n: usize = v.parse()?;
        if n == 0 {
            bail!("must be at least 1");
        }
        c.policy.draft_max = n;
        Ok(())
    }),
    opt!("policy_alpha", "policy: acceptance EWMA smoothing, (0, 1]", |c, v| {
        let f: f64 = v.parse()?;
        if !(f > 0.0 && f <= 1.0) {
            bail!("must be in (0, 1]");
        }
        c.policy.alpha = f;
        Ok(())
    }),
    opt!("policy_grow", "policy: acceptance EWMA that deepens the draft", |c, v| {
        c.policy.grow = v.parse()?;
        Ok(())
    }),
    opt!("policy_shrink", "policy: acceptance EWMA that shallows the draft", |c, v| {
        c.policy.shrink = v.parse()?;
        Ok(())
    }),
    opt!("policy_adjust_every", "policy: verify rounds between depth moves", |c, v| {
        let n: usize = v.parse()?;
        if n == 0 {
            bail!("must be at least 1");
        }
        c.policy.adjust_every = n;
        Ok(())
    }),
    opt!("drift_threshold", "policy: shortfall that forces a SpecPV refresh", |c, v| {
        let f: f64 = v.parse()?;
        if !(f > 0.0) {
            bail!("must be positive");
        }
        c.policy.drift_threshold = f;
        Ok(())
    }),
    opt!("policy_probe_rounds", "engine=auto: rounds before the acceptance probe vetoes", |c, v| {
        c.policy.probe_rounds = v.parse()?;
        Ok(())
    }),
    opt!("auto_short_prompt", "engine=auto: prompts below this decode ar", |c, v| {
        c.policy.auto_short = v.parse()?;
        Ok(())
    }),
    opt!("auto_long_prompt", "engine=auto: prompts at/above this go to spec_pv", |c, v| {
        c.policy.auto_long = v.parse()?;
        Ok(())
    }),
    opt!("faults", "failpoint spec, e.g. shard_panic@step=40,slow_op_ms=200 (\"\" = off)", |c, v| {
        // validate eagerly — a typo must not silently disable a chaos run
        crate::util::failpoint::FaultSpec::parse(v)?;
        c.faults = v.to_string();
        Ok(())
    }),
];

/// The declarative option table (config keys + CLI flags).
pub fn options() -> &'static [OptDef] {
    OPTIONS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert_eq!(c.specpv.retrieval_budget, 512);
        assert_eq!(c.specpv.core_tokens(32), 512 + 3 * 32);
        assert!(c.tree_size <= 16);
    }

    #[test]
    fn overrides() {
        let mut c = Config::default();
        let mut kv = BTreeMap::new();
        kv.insert("engine".to_string(), "triforce".to_string());
        kv.insert("retrieval_budget".to_string(), "256".to_string());
        kv.insert("reduction".to_string(), "last".to_string());
        kv.insert("max_active".to_string(), "8".to_string());
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.engine, EngineKind::TriForce);
        assert_eq!(c.specpv.retrieval_budget, 256);
        assert_eq!(c.specpv.reduction, Reduction::Last);
        assert_eq!(c.max_active, 8);
    }

    #[test]
    fn kv_and_admission_keys_parse() {
        let mut c = Config::default();
        assert_eq!(c.kv_budget_bytes, 0, "default: unlimited");
        assert!(c.prefix_cache_bytes > 0, "default: prefix cache on");
        let mut kv = BTreeMap::new();
        kv.insert("kv_budget_bytes".to_string(), "1048576".to_string());
        kv.insert("prefix_cache_bytes".to_string(), "0".to_string());
        kv.insert("max_queue".to_string(), "32".to_string());
        kv.insert("max_prompt".to_string(), "2048".to_string());
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.kv_budget_bytes, 1 << 20);
        assert_eq!(c.prefix_cache_bytes, 0);
        assert_eq!(c.max_queue, 32);
        assert_eq!(c.max_prompt, 2048);
    }

    #[test]
    fn threads_key_parses() {
        let mut c = Config::default();
        assert_eq!(c.threads, 0, "default: SPECPV_THREADS/auto");
        let mut kv = BTreeMap::new();
        kv.insert("threads".to_string(), "3".to_string());
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.threads, 3);
    }

    #[test]
    fn shard_keys_parse() {
        let mut c = Config::default();
        assert_eq!(c.shards, 1, "default: single-worker serving");
        assert_eq!(c.route_imbalance, 2.0);
        let mut kv = BTreeMap::new();
        kv.insert("shards".to_string(), "4".to_string());
        kv.insert("route_imbalance".to_string(), "1.5".to_string());
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.route_imbalance, 1.5);

        let mut bad = BTreeMap::new();
        bad.insert("shards".to_string(), "0".to_string());
        assert!(c.apply_overrides(&bad).is_err(), "shards must be >= 1");
        let mut bad = BTreeMap::new();
        bad.insert("route_imbalance".to_string(), "0.5".to_string());
        assert!(c.apply_overrides(&bad).is_err(), "imbalance must be >= 1.0");
    }

    #[test]
    fn fault_tolerance_keys_parse() {
        let mut c = Config::default();
        assert_eq!(c.checkpoint_every_steps, 0, "default: checkpoints off");
        assert_eq!(c.shard_queue, 0, "default: unbounded per-shard depth");
        assert_eq!(c.max_restarts, 3);
        assert_eq!(c.shard_heartbeat_ms, 0, "default: wedge detection off");
        assert!(c.faults.is_empty(), "default: failpoints off");
        let mut kv = BTreeMap::new();
        kv.insert("checkpoint_every_steps".to_string(), "8".to_string());
        kv.insert("shard_queue".to_string(), "64".to_string());
        kv.insert("max_restarts".to_string(), "1".to_string());
        kv.insert("shard_heartbeat_ms".to_string(), "250".to_string());
        kv.insert(
            "faults".to_string(),
            "shard_panic@step=40,backend_err_rate=0.01".to_string(),
        );
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.checkpoint_every_steps, 8);
        assert_eq!(c.shard_queue, 64);
        assert_eq!(c.max_restarts, 1);
        assert_eq!(c.shard_heartbeat_ms, 250);
        assert!(c.faults.contains("shard_panic"));

        let mut bad = BTreeMap::new();
        bad.insert("faults".to_string(), "nonsense=1".to_string());
        assert!(c.apply_overrides(&bad).is_err(), "bad failpoints rejected eagerly");
    }

    #[test]
    fn policy_keys_parse() {
        let mut c = Config::default();
        assert_eq!(c.policy.mode, PolicyMode::Fixed, "default: observe-only");
        assert!(!c.engine_auto, "default: static engine selection");
        assert_eq!(c.policy.draft_min, 1);
        assert_eq!(c.policy.draft_max, 6);
        let mut kv = BTreeMap::new();
        kv.insert("policy".to_string(), "adaptive".to_string());
        kv.insert("draft_min".to_string(), "2".to_string());
        kv.insert("draft_max".to_string(), "5".to_string());
        kv.insert("policy_alpha".to_string(), "0.5".to_string());
        kv.insert("policy_grow".to_string(), "0.9".to_string());
        kv.insert("policy_shrink".to_string(), "0.2".to_string());
        kv.insert("policy_adjust_every".to_string(), "2".to_string());
        kv.insert("drift_threshold".to_string(), "2.5".to_string());
        kv.insert("policy_probe_rounds".to_string(), "4".to_string());
        kv.insert("auto_short_prompt".to_string(), "32".to_string());
        kv.insert("auto_long_prompt".to_string(), "512".to_string());
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.policy.mode, PolicyMode::Adaptive);
        assert_eq!(c.policy.draft_min, 2);
        assert_eq!(c.policy.draft_max, 5);
        assert_eq!(c.policy.alpha, 0.5);
        assert_eq!(c.policy.grow, 0.9);
        assert_eq!(c.policy.shrink, 0.2);
        assert_eq!(c.policy.adjust_every, 2);
        assert_eq!(c.policy.drift_threshold, 2.5);
        assert_eq!(c.policy.probe_rounds, 4);
        assert_eq!(c.policy.auto_short, 32);
        assert_eq!(c.policy.auto_long, 512);

        let mut bad = BTreeMap::new();
        bad.insert("policy".to_string(), "magic".to_string());
        assert!(c.apply_overrides(&bad).is_err());
        let mut bad = BTreeMap::new();
        bad.insert("draft_min".to_string(), "0".to_string());
        assert!(c.apply_overrides(&bad).is_err(), "depth bound must be >= 1");
        let mut bad = BTreeMap::new();
        bad.insert("policy_alpha".to_string(), "1.5".to_string());
        assert!(c.apply_overrides(&bad).is_err(), "alpha must be in (0, 1]");
    }

    #[test]
    fn engine_auto_parses() {
        let mut c = Config::default();
        let mut kv = BTreeMap::new();
        kv.insert("engine".to_string(), "auto".to_string());
        c.apply_overrides(&kv).unwrap();
        assert!(c.engine_auto);
        assert_eq!(c.engine, EngineKind::SpecPv, "fallback engine untouched");
        // a concrete engine switches auto back off
        let mut kv = BTreeMap::new();
        kv.insert("engine".to_string(), "triforce".to_string());
        c.apply_overrides(&kv).unwrap();
        assert!(!c.engine_auto);
        assert_eq!(c.engine, EngineKind::TriForce);
    }

    #[test]
    fn policy_mode_parse_display() {
        for m in ["off", "fixed", "adaptive"] {
            let p: PolicyMode = m.parse().unwrap();
            assert_eq!(p.to_string(), m);
        }
        assert!("on".parse::<PolicyMode>().is_err());
    }

    #[test]
    fn bad_key_rejected() {
        let mut c = Config::default();
        let mut kv = BTreeMap::new();
        kv.insert("nope".to_string(), "1".to_string());
        assert!(c.apply_overrides(&kv).is_err());
    }

    #[test]
    fn backend_parse_display() {
        for b in ["auto", "pjrt", "reference"] {
            let k: BackendKind = b.parse().unwrap();
            assert_eq!(k.to_string(), b);
        }
        assert_eq!("ref".parse::<BackendKind>().unwrap(), BackendKind::Reference);
        assert!("cuda".parse::<BackendKind>().is_err());
        assert_eq!(Config::default().backend, BackendKind::Auto);
    }

    #[test]
    fn option_table_keys_are_unique_and_cover_every_override() {
        let mut seen = std::collections::BTreeSet::new();
        for def in options() {
            assert!(seen.insert(def.key), "duplicate option key '{}'", def.key);
            assert!(!def.help.is_empty(), "'{}' has no help text", def.key);
            if let Some(alias) = def.alias {
                assert_ne!(alias, def.flag(), "'{}' alias shadows its flag", def.key);
            }
        }
        // the paged-pool keys register exactly once, through the table
        for key in ["kv_page_bytes", "kv_swap_dir", "kv_quant"] {
            assert!(seen.contains(key), "'{key}' missing from the option table");
        }
    }

    #[test]
    fn paged_pool_keys_parse() {
        let mut c = Config::default();
        assert_eq!(c.kv_page_bytes, 64 << 10);
        assert!(c.swap_dir().is_none(), "default: no disk tier");
        assert_eq!(c.kv_quant, KvQuant::None);
        let mut kv = BTreeMap::new();
        kv.insert("kv_page_bytes".to_string(), "4096".to_string());
        kv.insert("kv_swap_dir".to_string(), "/tmp/kv".to_string());
        kv.insert("kv_quant".to_string(), "int8".to_string());
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.kv_page_bytes, 4096);
        assert_eq!(c.swap_dir(), Some(PathBuf::from("/tmp/kv")));
        assert_eq!(c.kv_quant, KvQuant::Int8);

        let mut bad = BTreeMap::new();
        bad.insert("kv_page_bytes".to_string(), "10".to_string());
        assert!(c.apply_overrides(&bad).is_err(), "page bytes must be 4-aligned");
        let mut bad = BTreeMap::new();
        bad.insert("kv_quant".to_string(), "fp8".to_string());
        assert!(c.apply_overrides(&bad).is_err());
    }

    #[test]
    fn kv_quant_parse_display() {
        for q in ["none", "int8"] {
            let k: KvQuant = q.parse().unwrap();
            assert_eq!(k.to_string(), q);
        }
    }

    #[test]
    fn journal_fsync_parse_display() {
        for s in ["always", "never", "interval_ms:250"] {
            let p: JournalFsync = s.parse().unwrap();
            assert_eq!(p.to_string(), s);
        }
        assert_eq!("interval_ms:0".parse::<JournalFsync>().unwrap(), JournalFsync::IntervalMs(0));
        assert!("sometimes".parse::<JournalFsync>().is_err());
        assert!("interval_ms:abc".parse::<JournalFsync>().is_err());
    }

    #[test]
    fn journal_keys_apply() {
        let mut c = Config::default();
        assert!(c.journal_path().is_none(), "journaling is off by default");
        let kv: BTreeMap<String, String> = [
            ("journal_dir".to_string(), "/tmp/j".to_string()),
            ("journal_fsync".to_string(), "interval_ms:50".to_string()),
        ]
        .into();
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.journal_path(), Some(PathBuf::from("/tmp/j")));
        assert_eq!(c.journal_fsync, JournalFsync::IntervalMs(50));
    }

    #[test]
    fn flags_are_dashed_keys() {
        let def = options().iter().find(|d| d.key == "kv_page_bytes").unwrap();
        assert_eq!(def.flag(), "kv-page-bytes");
        let def = options().iter().find(|d| d.key == "retrieval_budget").unwrap();
        assert_eq!(def.alias, Some("budget"), "legacy --budget alias kept");
    }

    #[test]
    fn reduction_parse_display() {
        for r in ["mean", "max", "last"] {
            let red: Reduction = r.parse().unwrap();
            assert_eq!(red.to_string(), r);
        }
        assert!("avg".parse::<Reduction>().is_err());
    }
}
