//! Configuration for the serving stack: model size, SpecPV cache geometry,
//! engine selection, offload simulation. Loadable from a simple `key=value`
//! file with CLI overrides (no TOML crate offline; the format is a strict
//! subset of TOML).

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

/// Retrieval score reduction over the verification step's queries
/// (paper Eq. 3 / Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reduction {
    Mean,
    Max,
    Last,
}

impl Reduction {
    /// Row index within the stacked `[mean, max, last]` score output of
    /// the `score_*` executables.
    pub fn row(self) -> usize {
        match self {
            Reduction::Mean => 0,
            Reduction::Max => 1,
            Reduction::Last => 2,
        }
    }
}

impl std::str::FromStr for Reduction {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "mean" => Ok(Reduction::Mean),
            "max" => Ok(Reduction::Max),
            "last" => Ok(Reduction::Last),
            _ => bail!("unknown reduction '{s}' (mean|max|last)"),
        }
    }
}

impl fmt::Display for Reduction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Reduction::Mean => "mean",
            Reduction::Max => "max",
            Reduction::Last => "last",
        })
    }
}

/// Decoding engine selection (paper §4.1 baselines + SpecPV).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// standard autoregressive decoding (the speedup denominator)
    Autoregressive,
    /// EAGLE3-YARN: tree speculation, full verification every step
    SpecFull,
    /// SpecPV: partial verification + periodic refresh (the paper)
    SpecPv,
    /// TriForce-like: independent tiny draft LM, full verification
    TriForce,
    /// TokenSwift-like: Medusa heads, full verification
    TokenSwift,
}

impl std::str::FromStr for EngineKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "ar" | "autoregressive" => Ok(EngineKind::Autoregressive),
            "spec_full" | "eagle3" => Ok(EngineKind::SpecFull),
            "spec_pv" | "specpv" => Ok(EngineKind::SpecPv),
            "triforce" => Ok(EngineKind::TriForce),
            "tokenswift" => Ok(EngineKind::TokenSwift),
            _ => bail!("unknown engine '{s}'"),
        }
    }
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            EngineKind::Autoregressive => "ar",
            EngineKind::SpecFull => "spec_full",
            EngineKind::SpecPv => "spec_pv",
            EngineKind::TriForce => "triforce",
            EngineKind::TokenSwift => "tokenswift",
        })
    }
}

/// Which device backend executes the kernel ops (`backend::Backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// pjrt when `artifacts/manifest.json` exists, reference otherwise
    Auto,
    /// PJRT CPU client over the AOT artifacts (`backend::pjrt`)
    Pjrt,
    /// pure-Rust host executor, no artifacts (`backend::reference`)
    Reference,
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(BackendKind::Auto),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            "reference" | "ref" | "host" => Ok(BackendKind::Reference),
            _ => bail!("unknown backend '{s}' (auto|pjrt|reference)"),
        }
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            BackendKind::Auto => "auto",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Reference => "reference",
        })
    }
}

/// SpecPV partial-cache geometry (paper §3.2). All unit = tokens unless
/// noted. `retrieval_budget` is the headline "SpecPV-xK" knob.
#[derive(Debug, Clone)]
pub struct SpecPvConfig {
    /// retrieval-segment budget in tokens (256 | 512 | 1024 here ≙ the
    /// paper's 2K | 4K | 8K at its 10× context scale)
    pub retrieval_budget: usize,
    /// attention-sink blocks always kept (tokens = blocks × block_size)
    pub sink_blocks: usize,
    /// local-window blocks always kept
    pub local_blocks: usize,
    /// buffer capacity: partially-verified tokens held before a Refresh
    /// is forced (paper default: one verification step's tokens + 20)
    pub buffer_cap: usize,
    /// score reduction f (paper Eq. 3)
    pub reduction: Reduction,
}

impl Default for SpecPvConfig {
    fn default() -> Self {
        SpecPvConfig {
            retrieval_budget: 512,
            sink_blocks: 1,
            local_blocks: 2,
            buffer_cap: 16 + 20,
            reduction: Reduction::Mean,
        }
    }
}

impl SpecPvConfig {
    /// Partial bucket required: core tokens (sink+retrieval+local) plus
    /// buffer headroom, rounded up to the compiled partial buckets.
    pub fn core_tokens(&self, block: usize) -> usize {
        (self.sink_blocks + self.local_blocks) * block + self.retrieval_budget
    }
}

/// Offload simulation (paper Fig. 4: RTX 4090 + PCIe KV offload).
#[derive(Debug, Clone)]
pub struct OffloadConfig {
    pub enabled: bool,
    /// effective host↔device bandwidth, GB/s (PCIe 4.0 x16 effective)
    pub pcie_gbps: f64,
    /// fraction of transfer hidden by per-layer prefetch overlap
    pub overlap: f64,
}

impl Default for OffloadConfig {
    fn default() -> Self {
        OffloadConfig { enabled: false, pcie_gbps: 12.0, overlap: 0.3 }
    }
}

/// Top-level serving configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub artifacts_dir: PathBuf,
    pub model_size: String,
    pub engine: EngineKind,
    /// device backend (auto: pjrt with artifacts, reference without)
    pub backend: BackendKind,
    pub specpv: SpecPvConfig,
    pub offload: OffloadConfig,
    pub temperature: f32,
    pub max_new_tokens: usize,
    /// draft tree: children of the root level
    pub tree_top_k: usize,
    /// draft tree: expansion depth (levels after the root)
    pub tree_depth: usize,
    /// total tree nodes (≤ compiled TREE_T)
    pub tree_size: usize,
    /// TriForce chain draft length γ
    pub chain_gamma: usize,
    pub server_addr: String,
    /// continuous-batching width: concurrent live sessions the
    /// coordinator's round-robin scheduler interleaves
    pub max_active: usize,
    /// admission: longest accepted prompt, tokens
    pub max_prompt: usize,
    /// admission: deepest request queue before submits are rejected
    pub max_queue: usize,
    /// KV state manager: byte budget for resident session state; the
    /// coordinator gates admission on it and swaps the lowest-priority
    /// session out under pressure (0 = unlimited, count-only admission)
    pub kv_budget_bytes: usize,
    /// KV state manager: byte budget of the prompt-prefix snapshot cache
    /// consulted by prefill (0 = disabled)
    pub prefix_cache_bytes: usize,
    /// kernel thread-pool width for the reference backend, mirroring the
    /// `SPECPV_THREADS` env override (0 = env/auto default); echoed in
    /// `Registry::summary`
    pub threads: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: PathBuf::from("artifacts"),
            model_size: "s".into(),
            engine: EngineKind::SpecPv,
            backend: BackendKind::Auto,
            specpv: SpecPvConfig::default(),
            offload: OffloadConfig::default(),
            temperature: 0.0,
            max_new_tokens: 256,
            tree_top_k: 4,
            tree_depth: 3,
            tree_size: 16,
            chain_gamma: 4,
            server_addr: "127.0.0.1:7799".into(),
            max_active: 4,
            max_prompt: 7 * 1024,
            max_queue: 256,
            kv_budget_bytes: 0,
            prefix_cache_bytes: 16 << 20,
            threads: 0,
        }
    }
}

impl Config {
    /// Parse a `key = value` config file (strict TOML subset: no sections,
    /// `#` comments, unquoted or double-quoted scalars).
    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config {path:?}: {e}"))?;
        let mut kv = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key=value", lineno + 1))?;
            kv.insert(
                k.trim().to_string(),
                v.trim().trim_matches('"').to_string(),
            );
        }
        let mut cfg = Config::default();
        cfg.apply_overrides(&kv)?;
        Ok(cfg)
    }

    /// Apply `key=value` overrides (also used for CLI `--set key=value`).
    pub fn apply_overrides(&mut self, kv: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in kv {
            match k.as_str() {
                "artifacts_dir" => self.artifacts_dir = PathBuf::from(v),
                "model_size" => self.model_size = v.clone(),
                "engine" => self.engine = v.parse()?,
                "backend" => self.backend = v.parse()?,
                "retrieval_budget" => {
                    self.specpv.retrieval_budget = v.parse()?
                }
                "sink_blocks" => self.specpv.sink_blocks = v.parse()?,
                "local_blocks" => self.specpv.local_blocks = v.parse()?,
                "buffer_cap" => self.specpv.buffer_cap = v.parse()?,
                "reduction" => self.specpv.reduction = v.parse()?,
                "offload" => self.offload.enabled = v.parse()?,
                "pcie_gbps" => self.offload.pcie_gbps = v.parse()?,
                "overlap" => self.offload.overlap = v.parse()?,
                "temperature" => self.temperature = v.parse()?,
                "max_new_tokens" => self.max_new_tokens = v.parse()?,
                "tree_top_k" => self.tree_top_k = v.parse()?,
                "tree_depth" => self.tree_depth = v.parse()?,
                "tree_size" => self.tree_size = v.parse()?,
                "chain_gamma" => self.chain_gamma = v.parse()?,
                "server_addr" => self.server_addr = v.clone(),
                "max_active" => self.max_active = v.parse()?,
                "max_prompt" => self.max_prompt = v.parse()?,
                "max_queue" => self.max_queue = v.parse()?,
                "kv_budget_bytes" => self.kv_budget_bytes = v.parse()?,
                "prefix_cache_bytes" => self.prefix_cache_bytes = v.parse()?,
                "threads" => self.threads = v.parse()?,
                _ => bail!("unknown config key '{k}'"),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert_eq!(c.specpv.retrieval_budget, 512);
        assert_eq!(c.specpv.core_tokens(32), 512 + 3 * 32);
        assert!(c.tree_size <= 16);
    }

    #[test]
    fn overrides() {
        let mut c = Config::default();
        let mut kv = BTreeMap::new();
        kv.insert("engine".to_string(), "triforce".to_string());
        kv.insert("retrieval_budget".to_string(), "256".to_string());
        kv.insert("reduction".to_string(), "last".to_string());
        kv.insert("max_active".to_string(), "8".to_string());
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.engine, EngineKind::TriForce);
        assert_eq!(c.specpv.retrieval_budget, 256);
        assert_eq!(c.specpv.reduction, Reduction::Last);
        assert_eq!(c.max_active, 8);
    }

    #[test]
    fn kv_and_admission_keys_parse() {
        let mut c = Config::default();
        assert_eq!(c.kv_budget_bytes, 0, "default: unlimited");
        assert!(c.prefix_cache_bytes > 0, "default: prefix cache on");
        let mut kv = BTreeMap::new();
        kv.insert("kv_budget_bytes".to_string(), "1048576".to_string());
        kv.insert("prefix_cache_bytes".to_string(), "0".to_string());
        kv.insert("max_queue".to_string(), "32".to_string());
        kv.insert("max_prompt".to_string(), "2048".to_string());
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.kv_budget_bytes, 1 << 20);
        assert_eq!(c.prefix_cache_bytes, 0);
        assert_eq!(c.max_queue, 32);
        assert_eq!(c.max_prompt, 2048);
    }

    #[test]
    fn threads_key_parses() {
        let mut c = Config::default();
        assert_eq!(c.threads, 0, "default: SPECPV_THREADS/auto");
        let mut kv = BTreeMap::new();
        kv.insert("threads".to_string(), "3".to_string());
        c.apply_overrides(&kv).unwrap();
        assert_eq!(c.threads, 3);
    }

    #[test]
    fn bad_key_rejected() {
        let mut c = Config::default();
        let mut kv = BTreeMap::new();
        kv.insert("nope".to_string(), "1".to_string());
        assert!(c.apply_overrides(&kv).is_err());
    }

    #[test]
    fn backend_parse_display() {
        for b in ["auto", "pjrt", "reference"] {
            let k: BackendKind = b.parse().unwrap();
            assert_eq!(k.to_string(), b);
        }
        assert_eq!("ref".parse::<BackendKind>().unwrap(), BackendKind::Reference);
        assert!("cuda".parse::<BackendKind>().is_err());
        assert_eq!(Config::default().backend, BackendKind::Auto);
    }

    #[test]
    fn reduction_parse_display() {
        for r in ["mean", "max", "last"] {
            let red: Reduction = r.parse().unwrap();
            assert_eq!(red.to_string(), r);
        }
        assert!("avg".parse::<Reduction>().is_err());
    }
}
