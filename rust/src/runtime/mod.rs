//! PJRT runtime: loads the AOT artifacts (HLO text) and executes them on
//! the CPU PJRT client with device-resident state threading. This is the
//! low-level machinery behind [`crate::backend::pjrt::PjrtBackend`] —
//! engines talk to the typed [`crate::backend::Backend`] op API, never to
//! `invoke` directly.
//!
//! Key design points (see DESIGN.md §4 and aot.py's FLAT-STATE ABI note):
//! * executables are compiled lazily on first use and cached — a process
//!   only pays for the (size, bucket, T) variants its run touches;
//! * weights are uploaded once per model size and reused as device
//!   buffers across every call (`execute_b`);
//! * each stateful executable returns exactly one flat f32 state buffer,
//!   which stays on device and is passed straight into the next call —
//!   zero host↔device KV traffic in steady state;
//! * small host-visible results flow through the tiny `read_*` extractor
//!   executables (the CPU client implements neither result untupling nor
//!   CopyRawToHost).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::backend::Counters;
use crate::manifest::{ArgSpec, DType, ExecSpec, Manifest};
use crate::weights::Weights;

/// A per-call argument value. Weight arguments are appended automatically
/// by the runtime in manifest order.
pub enum Arg<'a> {
    /// i32 tensor (tokens, positions, indices)
    I32(&'a [i32]),
    /// f32 tensor (tree masks, features)
    F32(&'a [f32]),
    /// i32 scalar (kv_len, n_prev, …)
    Scalar(i32),
    /// a device-resident buffer (threaded state, another exec's output)
    Buf(&'a PjRtBuffer),
}

pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    compiled: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    weight_bufs: RefCell<HashMap<String, Rc<Vec<(String, PjRtBuffer)>>>>,
    pub counters: RefCell<Counters>,
}

impl Runtime {
    /// Create a runtime over an artifacts directory (must contain
    /// `manifest.json`, the `*.hlo.txt` files and the weights binaries).
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            compiled: RefCell::new(HashMap::new()),
            weight_bufs: RefCell::new(HashMap::new()),
            counters: RefCell::new(Counters::default()),
        })
    }

    fn compile(&self, spec: &ExecSpec) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.compiled.borrow().get(&spec.name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf8")?,
        )
        .map_err(|e| anyhow::anyhow!("loading {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e}", spec.name))?,
        );
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut c = self.counters.borrow_mut();
            c.compilations += 1;
            c.compile_secs += dt;
        }
        self.compiled
            .borrow_mut()
            .insert(spec.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Upload (once) and return the weight buffer set for a model size.
    fn weights_for(&self, size: &str) -> Result<Rc<Vec<(String, PjRtBuffer)>>> {
        if let Some(w) = self.weight_bufs.borrow().get(size) {
            return Ok(w.clone());
        }
        let info = self.manifest.model(size)?;
        let w = Weights::load(&self.manifest.dir.join(&info.weights_file))?;
        let mut bufs = Vec::new();
        let mut bytes = 0u64;
        for (name, t) in &w.tensors {
            let buf = self
                .client
                .buffer_from_host_buffer(&t.data, &t.dims, None)
                .map_err(|e| anyhow::anyhow!("uploading {name}: {e}"))?;
            bytes += (t.data.len() * 4) as u64;
            bufs.push((name.clone(), buf));
        }
        self.counters.borrow_mut().upload_bytes += bytes;
        let rc = Rc::new(bufs);
        self.weight_bufs
            .borrow_mut()
            .insert(size.to_string(), rc.clone());
        Ok(rc)
    }

    /// Upload a host f32 tensor as a device buffer.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.counters.borrow_mut().upload_bytes += (data.len() * 4) as u64;
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload_f32: {e}"))
    }

    /// Fresh all-zero state buffer of `n` f32 elements.
    pub fn zero_state(&self, n: usize) -> Result<PjRtBuffer> {
        self.upload_f32(&vec![0f32; n], &[n])
    }

    /// Download a whole f32 device buffer to the host.
    pub fn download_f32(&self, buf: &PjRtBuffer) -> Result<Vec<f32>> {
        let lit: Literal = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e}"))?;
        let v = lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))?;
        self.counters.borrow_mut().download_bytes += (v.len() * 4) as u64;
        Ok(v)
    }

    /// Invoke an executable by manifest name. `inputs` must cover the
    /// non-weight arguments in manifest order; weight args are appended
    /// automatically from the per-size weight set. Returns the single
    /// output buffer (flat state or extractor result).
    pub fn invoke(&self, name: &str, inputs: &[Arg]) -> Result<PjRtBuffer> {
        let spec = self.manifest.exec(name)?.clone();
        let exe = self.compile(&spec)?;

        let call_args: Vec<&ArgSpec> =
            spec.args.iter().filter(|a| !a.is_weight()).collect();
        if call_args.len() != inputs.len() {
            bail!(
                "{name}: expected {} call args, got {}",
                call_args.len(),
                inputs.len()
            );
        }

        // Uploaded temporaries must outlive the arg-ref vector, so the
        // pass is two-phase: resolve every manifest arg to an indexed
        // `Slot`, then materialise the `&PjRtBuffer` list.
        enum Slot<'s> {
            /// uploaded host temporary (index into `tmp`)
            Tmp(usize),
            /// caller-provided device buffer (threaded state)
            Ext(&'s PjRtBuffer),
            /// per-size weight set entry (index into `weights`)
            Weight(usize),
        }
        let mut tmp: Vec<PjRtBuffer> = Vec::new();
        let mut slots: Vec<Slot> = Vec::with_capacity(spec.args.len());

        let mut input_iter = inputs.iter();
        let weights = if spec.args.iter().any(|a| a.is_weight()) {
            Some(self.weights_for(&spec.size)?)
        } else {
            None
        };
        for a in &spec.args {
            if a.is_weight() {
                let ws = weights.as_ref().unwrap();
                // weight args appear in manifest order == sorted order ==
                // BTreeMap iteration order, but draft executables mix "d."
                // and "t." groups — look up by name for robustness.
                let pos = ws
                    .iter()
                    .position(|(n, _)| n == &a.name)
                    .with_context(|| format!("{name}: weight {} missing", a.name))?;
                slots.push(Slot::Weight(pos));
                continue;
            }
            let v = input_iter.next().unwrap();
            match v {
                Arg::I32(xs) => {
                    if xs.len() != a.elems() {
                        bail!("{name}: arg {} wants {} i32, got {}",
                              a.name, a.elems(), xs.len());
                    }
                    if a.dtype != DType::I32 {
                        bail!("{name}: arg {} is not i32", a.name);
                    }
                    let b = self
                        .client
                        .buffer_from_host_buffer(xs, &a.shape, None)
                        .map_err(|e| anyhow::anyhow!("{name}/{}: {e}", a.name))?;
                    tmp.push(b);
                    slots.push(Slot::Tmp(tmp.len() - 1));
                }
                Arg::F32(xs) => {
                    if xs.len() != a.elems() || a.dtype != DType::F32 {
                        bail!("{name}: arg {} f32 shape mismatch", a.name);
                    }
                    let b = self
                        .client
                        .buffer_from_host_buffer(xs, &a.shape, None)
                        .map_err(|e| anyhow::anyhow!("{name}/{}: {e}", a.name))?;
                    tmp.push(b);
                    slots.push(Slot::Tmp(tmp.len() - 1));
                }
                Arg::Scalar(x) => {
                    if !a.shape.is_empty() {
                        bail!("{name}: arg {} is not scalar", a.name);
                    }
                    let b = self
                        .client
                        .buffer_from_host_buffer(&[*x], &[], None)
                        .map_err(|e| anyhow::anyhow!("{name}/{}: {e}", a.name))?;
                    tmp.push(b);
                    slots.push(Slot::Tmp(tmp.len() - 1));
                }
                Arg::Buf(b) => slots.push(Slot::Ext(*b)),
            }
        }

        let refs: Vec<&PjRtBuffer> = slots
            .iter()
            .map(|s| match s {
                Slot::Tmp(i) => &tmp[*i],
                Slot::Ext(b) => *b,
                Slot::Weight(i) => &weights.as_ref().unwrap()[*i].1,
            })
            .collect();

        let t0 = Instant::now();
        let mut outs = exe
            .execute_b(&refs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut c = self.counters.borrow_mut();
            c.executions += 1;
            c.exec_secs += dt;
            let e = c.per_exec.entry(name.to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += dt;
        }
        let mut replica = outs
            .pop()
            .and_then(|mut r| if r.is_empty() { None } else { Some(r.remove(0)) });
        // execute_b returns outputs[replica][buffer]; single replica here
        // (first Vec level is per-output for untupled single results)
        match replica.take() {
            Some(b) => Ok(b),
            None => bail!("{name}: no output buffer"),
        }
    }

    /// Convenience: invoke + download (for extractor executables).
    pub fn invoke_download(&self, name: &str, inputs: &[Arg]) -> Result<Vec<f32>> {
        let b = self.invoke(name, inputs)?;
        self.download_f32(&b)
    }
}

#[cfg(test)]
mod tests {
    // Runtime integration tests live in rust/tests/ (they need artifacts);
    // here we only check pure helpers.
}
