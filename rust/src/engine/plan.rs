//! The **plan/apply protocol** behind cross-session batched decode
//! (DESIGN.md §12).
//!
//! An [`EngineSession`](super::EngineSession) that supports the protocol
//! splits each `step()` into a resumable state machine driven by
//! `drive()`: host-side work (tree building, sampling, cache accounting,
//! non-batchable backend ops) runs inline, and every *batchable* kernel
//! op — the ops whose cost is dominated by streaming weight matrices —
//! is surfaced as a [`KernelPlan`] instead of being executed
//! immediately. The coordinator collects the plans of every active
//! session, groups them by [`PlanKey`] (op class + model size + bucket +
//! token width) and issues each group as **one** batched backend
//! invocation, then resumes each session's `drive()` to consume the
//! results (which live in the mutated state buffer — plans carry inputs,
//! never outputs).
//!
//! `step()` for protocol sessions is the degenerate single-session loop
//! over the same machine (`drive` → [`exec_single`] → `drive` …), so the
//! batched and unbatched paths execute the *identical* op sequence —
//! byte parity between them reduces to the backend's batched-op parity
//! contract, pinned by `rust/tests/batched_parity.rs`.

use anyhow::{bail, Result};

use crate::backend::{
    Backend, DraftExpandOp, PrefillOp, StateBuf, TinyForwardOp, VerifyOp,
};

use super::StepOutcome;

/// Which batchable kernel op a [`KernelPlan`] describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    Prefill,
    VerifyFull,
    VerifyPartial,
    DraftExpand,
    TinyForward,
}

/// Grouping key for batched execution: plans with equal keys are
/// geometry-compatible and may run as one fused backend invocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanKey {
    pub class: OpClass,
    pub size: String,
    pub bucket: usize,
    pub t: usize,
}

/// One pending batchable kernel op with **owned** inputs, so the
/// coordinator can hold the op descriptor and the state buffer it
/// mutates at the same time. Field meaning follows the corresponding
/// `backend` op struct; unused fields stay empty/zero per class.
#[derive(Debug)]
pub struct KernelPlan {
    pub class: OpClass,
    pub size: String,
    pub bucket: usize,
    /// token-slot width (chunk for prefill, W for draft expand)
    pub t: usize,
    pub tokens: Vec<i32>,
    pub pos: Vec<i32>,
    pub mask: Vec<f32>,
    pub kv_len: usize,
    /// draft expand / tiny forward write cursor
    pub write_pos: usize,
    /// tiny forward: which row's logits the state keeps
    pub last_idx: usize,
    /// verify ops: fused-compaction rows of the previous step
    pub prev_idx: Vec<i32>,
    pub n_prev: usize,
    /// draft expand: `[W, 3h]` fused features
    pub feats: Vec<f32>,
}

impl KernelPlan {
    /// A plan with every optional field empty (callers fill in the
    /// class-specific ones).
    pub fn new(class: OpClass, size: &str, bucket: usize, t: usize) -> KernelPlan {
        KernelPlan {
            class,
            size: size.to_string(),
            bucket,
            t,
            tokens: Vec::new(),
            pos: Vec::new(),
            mask: Vec::new(),
            kv_len: 0,
            write_pos: 0,
            last_idx: 0,
            prev_idx: Vec::new(),
            n_prev: 0,
            feats: Vec::new(),
        }
    }

    pub fn key(&self) -> PlanKey {
        PlanKey { class: self.class, size: self.size.clone(), bucket: self.bucket, t: self.t }
    }

    fn as_verify(&self) -> VerifyOp<'_> {
        VerifyOp {
            size: &self.size,
            bucket: self.bucket,
            t: self.t,
            tokens: &self.tokens,
            pos: &self.pos,
            mask: &self.mask,
            kv_len: self.kv_len,
            prev_idx: &self.prev_idx,
            n_prev: self.n_prev,
        }
    }

    fn as_prefill(&self) -> PrefillOp<'_> {
        PrefillOp {
            size: &self.size,
            bucket: self.bucket,
            tokens: &self.tokens,
            pos: &self.pos,
            mask: &self.mask,
            kv_len: self.kv_len,
        }
    }

    fn as_draft_expand(&self) -> DraftExpandOp<'_> {
        DraftExpandOp {
            size: &self.size,
            bucket: self.bucket,
            tokens: &self.tokens,
            feats: &self.feats,
            pos: &self.pos,
            mask: &self.mask,
            kv_len: self.kv_len,
            write_pos: self.write_pos,
        }
    }

    fn as_tiny(&self) -> TinyForwardOp<'_> {
        TinyForwardOp {
            t: self.t,
            tokens: &self.tokens,
            pos: &self.pos,
            mask: &self.mask,
            kv_len: self.kv_len,
            write_pos: self.write_pos,
            last_idx: self.last_idx,
        }
    }
}

/// What `EngineSession::drive` reports.
#[derive(Debug)]
pub enum Drive {
    /// A batchable kernel op is pending; the caller executes it (alone
    /// or fused into a group) and calls `drive()` again.
    Pending,
    /// The scheduler-visible step finished; here is its outcome.
    Complete(StepOutcome),
    /// This session does not implement the protocol — use `step()`.
    Unsupported,
}

/// Execute one plan against one state in place (the single-session path
/// and the width-1 group path — always the *unbatched* backend entry
/// point, so `step()` semantics are exactly the pre-protocol ones).
pub fn exec_single(be: &dyn Backend, plan: &KernelPlan, state: &mut StateBuf) -> Result<()> {
    let owned = std::mem::replace(state, StateBuf::nil());
    let out = match plan.class {
        OpClass::Prefill => be.prefill(&plan.as_prefill(), owned)?,
        OpClass::VerifyFull => be.verify_full(&plan.as_verify(), owned)?,
        OpClass::VerifyPartial => be.verify_partial(&plan.as_verify(), owned)?,
        OpClass::DraftExpand => be.draft_expand(&plan.as_draft_expand(), owned)?,
        OpClass::TinyForward => be.tiny_forward(&plan.as_tiny(), owned)?,
    };
    *state = out;
    Ok(())
}

/// Execute a geometry-compatible group of plans as one batched backend
/// invocation. All plans must share one [`PlanKey`] (the coordinator
/// groups by it); byte parity with per-plan [`exec_single`] calls is the
/// backend's batched-op contract.
pub fn exec_batch(
    be: &dyn Backend,
    plans: &[&KernelPlan],
    states: &mut [&mut StateBuf],
) -> Result<()> {
    let Some(first) = plans.first() else { return Ok(()) };
    if plans.len() != states.len() {
        bail!("plan count {} != state count {}", plans.len(), states.len());
    }
    match first.class {
        OpClass::Prefill => {
            let ops: Vec<PrefillOp> = plans.iter().map(|p| p.as_prefill()).collect();
            be.prefill_batch(&ops, states)
        }
        OpClass::VerifyFull => {
            let ops: Vec<VerifyOp> = plans.iter().map(|p| p.as_verify()).collect();
            be.verify_full_batch(&ops, states)
        }
        OpClass::VerifyPartial => {
            let ops: Vec<VerifyOp> = plans.iter().map(|p| p.as_verify()).collect();
            be.verify_partial_batch(&ops, states)
        }
        OpClass::DraftExpand => {
            let ops: Vec<DraftExpandOp> = plans.iter().map(|p| p.as_draft_expand()).collect();
            be.draft_expand_batch(&ops, states)
        }
        OpClass::TinyForward => {
            let ops: Vec<TinyForwardOp> = plans.iter().map(|p| p.as_tiny()).collect();
            be.tiny_forward_batch(&ops, states)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_key_groups_by_geometry() {
        let a = KernelPlan::new(OpClass::VerifyFull, "s", 1024, 16);
        let b = KernelPlan::new(OpClass::VerifyFull, "s", 1024, 16);
        let c = KernelPlan::new(OpClass::VerifyFull, "s", 1024, 48);
        let d = KernelPlan::new(OpClass::VerifyPartial, "s", 1024, 16);
        assert_eq!(a.key(), b.key());
        assert_ne!(a.key(), c.key(), "width must split groups");
        assert_ne!(a.key(), d.key(), "op class must split groups");
    }

    #[test]
    fn exec_batch_rejects_mismatched_arity() {
        let be = crate::backend::reference::ReferenceBackend::new();
        let plan = KernelPlan::new(OpClass::VerifyFull, "s", 128, 1);
        assert!(exec_batch(&be, &[&plan], &mut []).is_err());
    }
}
