//! EAGLE-3-style tree drafting controller (paper §3.1/Fig. 3).
//!
//! Per decode round, matching the training-time-test conventions of
//! `train.py::draft_ttt_loss` exactly:
//! 1. **catch-up chain** (pass-0 convention) — the previous step's
//!    accepted path tokens run through the draft layer paired with their
//!    *target* features, committing clean draft-KV rows;
//! 2. **bonus step** (pass-1 convention) — the bonus token runs with the
//!    *recycled draft hidden* of its predecessor (the deepest accepted
//!    token, or the prompt tail after prefill); its logits seed the
//!    tree's first children;
//! 3. **level expansions** (pass-k) — `depth-1` rounds of node expansion
//!    over the scratch region, recycling each node's own hidden;
//! 4. **prune** — keep the best `tree_size` nodes by cumulative draft
//!    log-probability (EAGLE-2-style top-N selection).
//!
//! The round is a resumable state machine ([`DraftTreeRun`]) so the
//! coordinator can fuse the draft-expand kernel ops of concurrent
//! sessions (plan/apply protocol, DESIGN.md §12): `next_op` runs the
//! host-side tree bookkeeping up to the next `draft_expand` and returns
//! it as a [`KernelPlan`]; after the caller executes the plan (alone or
//! batched), the following `next_op` call consumes the expand's outputs
//! and continues. [`draft_tree`] is the run-to-completion convenience
//! over the same machine.

use std::collections::HashMap;

use anyhow::Result;

use crate::config::Config;
use crate::sampling::{log_softmax, top_k};
use crate::tree::Tree;

use super::plan::{exec_single, KernelPlan};
use super::session::DraftSession;

/// Tile a hidden state (h) to the 3h fused-feature width (model.recycle).
/// The tick path below tiles straight into the feats buffer via `tile3`;
/// this allocating form is kept for callers that need an owned feature.
pub fn recycle(hidden: &[f32]) -> Vec<f32> {
    let mut v = vec![0f32; hidden.len() * 3];
    tile3(&mut v, hidden);
    v
}

/// `recycle` into an existing `[3h]` slot — the per-node tick path uses
/// this to tile hiddens straight into the feats buffer without the
/// intermediate allocation.
fn tile3(dst: &mut [f32], hidden: &[f32]) {
    let h = hidden.len();
    debug_assert_eq!(dst.len(), 3 * h);
    for s in 0..3 {
        dst[s * h..(s + 1) * h].copy_from_slice(hidden);
    }
}

/// Inputs for one drafting round.
pub struct DraftInputs {
    /// accepted path to catch up on: (token, fused target feature 3h)
    pub chain: Vec<(u32, Vec<f32>)>,
    /// the bonus token (tree root)
    pub bonus: u32,
    /// absolute position of the first chain token
    pub chain_start_pos: usize,
    /// recycled-hidden feature for the bonus when the chain is empty
    /// (i.e. the draft hidden of the last committed draft row); when the
    /// chain is non-empty the hidden comes from the chain call itself
    pub prev_hidden: Vec<f32>,
}

/// Output: the pruned tree plus the draft hidden of the bonus token
/// (becomes `prev_hidden` when the next round's path is empty).
pub struct DraftRound {
    pub tree: Tree,
    pub bonus_hidden: Vec<f32>,
}

/// Per-node bookkeeping: scratch ancestors + untiled hidden.
struct Meta {
    anc: Vec<usize>,
    hidden: Vec<f32>,
}

/// Where a [`DraftTreeRun`] is between `next_op` calls. `After*` stages
/// mean a planned op's execution is pending consumption.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Stage {
    Start,
    AfterChain,
    AfterBonus,
    LevelBegin,
    AfterLevel,
    Done,
}

/// One drafting round as a resumable state machine over the draft
/// session's batchable expand ops.
pub struct DraftTreeRun {
    top_k: usize,
    depth: usize,
    size_cap: usize,
    inp: DraftInputs,
    stage: Stage,
    tree: Tree,
    meta: HashMap<usize, Meta>,
    frontier: Vec<usize>,
    root_pos: usize,
    root_hidden: Vec<f32>,
    level: usize,
    chain_n: usize,
    /// parents of the level expand in flight, slot order
    parents: Vec<usize>,
    /// scratch offsets of the in-flight level's rows
    offsets: Vec<usize>,
}

impl DraftTreeRun {
    pub fn new(cfg: &Config, inp: DraftInputs) -> DraftTreeRun {
        let tree = Tree::new(inp.bonus);
        let root_pos = inp.chain_start_pos + inp.chain.len();
        DraftTreeRun {
            top_k: cfg.tree_top_k,
            depth: cfg.tree_depth,
            size_cap: cfg.tree_size,
            inp,
            stage: Stage::Start,
            tree,
            meta: HashMap::new(),
            frontier: Vec::new(),
            root_pos,
            root_hidden: Vec::new(),
            level: 1,
            chain_n: 0,
            parents: Vec::new(),
            offsets: Vec::new(),
        }
    }

    /// Plan the bonus step (pass-1): the bonus token with the recycled
    /// predecessor hidden.
    fn plan_bonus(
        &mut self,
        draft: &mut DraftSession,
        prev_hidden: &[f32],
    ) -> Result<(KernelPlan, usize)> {
        let w = draft.consts.draft_w;
        let f3 = 3 * draft.info.d_model;
        let mut feats = vec![0f32; w * f3];
        tile3(&mut feats[..f3], prev_hidden);
        draft.plan_chain(&[self.inp.bonus], &feats, self.root_pos)
    }

    /// Advance to the next pending draft-expand op, consuming the
    /// previous one's outputs on the way. Returns `None` once the round
    /// is complete (then call [`DraftTreeRun::finish`]).
    pub fn next_op(&mut self, draft: &mut DraftSession) -> Result<Option<KernelPlan>> {
        loop {
            match self.stage {
                Stage::Start => {
                    let n_chain = self.inp.chain.len();
                    if n_chain > 0 {
                        let w = draft.consts.draft_w;
                        let f3 = 3 * draft.info.d_model;
                        assert!(n_chain <= w, "chain {n_chain} exceeds draft width {w}");
                        let tokens: Vec<u32> = self.inp.chain.iter().map(|(t, _)| *t).collect();
                        let mut feats = vec![0f32; w * f3];
                        for (i, (_, f)) in self.inp.chain.iter().enumerate() {
                            feats[i * f3..(i + 1) * f3].copy_from_slice(f);
                        }
                        let (plan, n) =
                            draft.plan_chain(&tokens, &feats, self.inp.chain_start_pos)?;
                        self.chain_n = n;
                        self.stage = Stage::AfterChain;
                        return Ok(Some(plan));
                    }
                    let prev = std::mem::take(&mut self.inp.prev_hidden);
                    let (plan, _) = self.plan_bonus(draft, &prev)?;
                    self.stage = Stage::AfterBonus;
                    return Ok(Some(plan));
                }
                Stage::AfterChain => {
                    let out = draft.finish_chain(self.chain_n)?;
                    let prev = out.hidden(self.chain_n - 1).to_vec();
                    let (plan, _) = self.plan_bonus(draft, &prev)?;
                    self.stage = Stage::AfterBonus;
                    return Ok(Some(plan));
                }
                Stage::AfterBonus => {
                    let out = draft.finish_chain(1)?;
                    let root_logits = log_softmax(out.logits(0));
                    self.root_hidden = out.hidden(0).to_vec();
                    for &tk in top_k(&root_logits, self.top_k).iter() {
                        let idx = self.tree.add(0, tk as u32, root_logits[tk]);
                        self.meta.insert(
                            idx,
                            Meta { anc: Vec::new(), hidden: self.root_hidden.clone() },
                        );
                        self.frontier.push(idx);
                    }
                    self.level = 1;
                    self.stage = Stage::LevelBegin;
                }
                Stage::LevelBegin => {
                    if self.level >= self.depth || self.frontier.is_empty() {
                        self.stage = Stage::Done;
                        return Ok(None);
                    }
                    let w = draft.consts.draft_w;
                    let f3 = 3 * draft.info.d_model;
                    self.frontier.sort_by(|&a, &b| {
                        self.tree.nodes[b]
                            .score
                            .partial_cmp(&self.tree.nodes[a].score)
                            .unwrap_or(std::cmp::Ordering::Equal)
                    });
                    self.frontier.truncate(w.min(self.top_k));
                    let toks: Vec<u32> =
                        self.frontier.iter().map(|&i| self.tree.nodes[i].token).collect();
                    let mut fts = vec![0f32; w * f3];
                    let mut ancs: Vec<Vec<usize>> = Vec::with_capacity(self.frontier.len());
                    let mut pos: Vec<i32> = Vec::with_capacity(w);
                    for (s, &ti) in self.frontier.iter().enumerate() {
                        let m = &self.meta[&ti];
                        tile3(&mut fts[s * f3..(s + 1) * f3], &m.hidden);
                        ancs.push(m.anc.clone());
                        pos.push((self.root_pos + self.tree.nodes[ti].depth) as i32);
                    }
                    for _ in self.frontier.len()..w {
                        pos.push(*pos.last().unwrap_or(&(self.root_pos as i32)));
                    }
                    let (plan, offsets) = draft.plan_level(&toks, &fts, &pos, &ancs)?;
                    self.parents = std::mem::take(&mut self.frontier);
                    self.offsets = offsets;
                    self.stage = Stage::AfterLevel;
                    return Ok(Some(plan));
                }
                Stage::AfterLevel => {
                    let out = draft.finish_level()?;
                    let parents = std::mem::take(&mut self.parents);
                    for (s, &pi) in parents.iter().enumerate() {
                        let lp = log_softmax(out.logits(s));
                        let hid = out.hidden(s);
                        let mut panc = self.meta[&pi].anc.clone();
                        panc.push(self.offsets[s]);
                        for &tk in top_k(&lp, 2).iter() {
                            let idx = self.tree.add(pi, tk as u32, lp[tk]);
                            self.meta
                                .insert(idx, Meta { anc: panc.clone(), hidden: hid.to_vec() });
                            self.frontier.push(idx);
                        }
                    }
                    self.level += 1;
                    self.stage = Stage::LevelBegin;
                }
                Stage::Done => return Ok(None),
            }
        }
    }

    /// Package the round once [`DraftTreeRun::next_op`] returned `None`.
    pub fn finish(self) -> DraftRound {
        DraftRound {
            tree: self.tree.prune_top(self.size_cap),
            bonus_hidden: self.root_hidden,
        }
    }
}

/// Run one full drafting round to completion (the single-session path:
/// every planned expand executes immediately and unbatched).
pub fn draft_tree(
    draft: &mut DraftSession,
    cfg: &Config,
    inp: DraftInputs,
) -> Result<DraftRound> {
    let mut run = DraftTreeRun::new(cfg, inp);
    while let Some(plan) = run.next_op(draft)? {
        exec_single(draft.backend(), &plan, &mut draft.state)?;
    }
    Ok(run.finish())
}
