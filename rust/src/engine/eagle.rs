//! EAGLE-3-style tree drafting controller (paper §3.1/Fig. 3).
//!
//! Per decode round, matching the training-time-test conventions of
//! `train.py::draft_ttt_loss` exactly:
//! 1. **catch-up chain** (pass-0 convention) — the previous step's
//!    accepted path tokens run through the draft layer paired with their
//!    *target* features, committing clean draft-KV rows;
//! 2. **bonus step** (pass-1 convention) — the bonus token runs with the
//!    *recycled draft hidden* of its predecessor (the deepest accepted
//!    token, or the prompt tail after prefill); its logits seed the
//!    tree's first children;
//! 3. **level expansions** (pass-k) — `depth-1` rounds of node expansion
//!    over the scratch region, recycling each node's own hidden;
//! 4. **prune** — keep the best `tree_size` nodes by cumulative draft
//!    log-probability (EAGLE-2-style top-N selection).

use std::collections::HashMap;

use anyhow::Result;

use crate::config::Config;
use crate::sampling::{log_softmax, top_k};
use crate::tree::Tree;

use super::session::DraftSession;

/// Tile a hidden state (h) to the 3h fused-feature width (model.recycle).
/// The tick path below tiles straight into the feats buffer via `tile3`;
/// this allocating form is kept for callers that need an owned feature.
pub fn recycle(hidden: &[f32]) -> Vec<f32> {
    let mut v = vec![0f32; hidden.len() * 3];
    tile3(&mut v, hidden);
    v
}

/// `recycle` into an existing `[3h]` slot — the per-node tick path uses
/// this to tile hiddens straight into the feats buffer without the
/// intermediate allocation.
fn tile3(dst: &mut [f32], hidden: &[f32]) {
    let h = hidden.len();
    debug_assert_eq!(dst.len(), 3 * h);
    for s in 0..3 {
        dst[s * h..(s + 1) * h].copy_from_slice(hidden);
    }
}

/// Inputs for one drafting round.
pub struct DraftInputs {
    /// accepted path to catch up on: (token, fused target feature 3h)
    pub chain: Vec<(u32, Vec<f32>)>,
    /// the bonus token (tree root)
    pub bonus: u32,
    /// absolute position of the first chain token
    pub chain_start_pos: usize,
    /// recycled-hidden feature for the bonus when the chain is empty
    /// (i.e. the draft hidden of the last committed draft row); when the
    /// chain is non-empty the hidden comes from the chain call itself
    pub prev_hidden: Vec<f32>,
}

/// Output: the pruned tree plus the draft hidden of the bonus token
/// (becomes `prev_hidden` when the next round's path is empty).
pub struct DraftRound {
    pub tree: Tree,
    pub bonus_hidden: Vec<f32>,
}

/// Run one full drafting round.
pub fn draft_tree(
    draft: &mut DraftSession,
    cfg: &Config,
    inp: &DraftInputs,
) -> Result<DraftRound> {
    let w = draft.consts.draft_w;
    let h = draft.info.d_model;
    let f3 = 3 * h;

    // --- 1. catch-up chain (pass-0: target features) ----------------------
    let n_chain = inp.chain.len();
    let chain_out;
    let prev_hidden: &[f32] = if n_chain > 0 {
        assert!(n_chain <= w, "chain {n_chain} exceeds draft width {w}");
        let tokens: Vec<u32> = inp.chain.iter().map(|(t, _)| *t).collect();
        let mut feats = vec![0f32; w * f3];
        for (i, (_, f)) in inp.chain.iter().enumerate() {
            feats[i * f3..(i + 1) * f3].copy_from_slice(f);
        }
        chain_out = draft.chain(&tokens, &feats, inp.chain_start_pos)?;
        chain_out.hidden(n_chain - 1)
    } else {
        &inp.prev_hidden
    };

    // --- 2. bonus step (pass-1: recycled predecessor hidden) --------------
    let root_pos = inp.chain_start_pos + n_chain;
    let mut feats = vec![0f32; w * f3];
    tile3(&mut feats[..f3], prev_hidden);
    let out = draft.chain(&[inp.bonus], &feats, root_pos)?;
    let root_logits = log_softmax(out.logits(0));
    let root_hidden = out.hidden(0).to_vec();

    let mut tree = Tree::new(inp.bonus);

    // node bookkeeping: tree idx → (scratch ancestors, node hidden);
    // keyed map instead of the old linear-scan pair list, and hiddens are
    // stored untiled (h, not 3h) and tiled straight into the feats buffer
    struct Meta {
        anc: Vec<usize>,
        hidden: Vec<f32>,
    }
    let mut meta: HashMap<usize, Meta> = HashMap::new();

    // --- 3a. level 1: root's children --------------------------------------
    let mut frontier: Vec<usize> = Vec::new();
    for &tk in top_k(&root_logits, cfg.tree_top_k).iter() {
        let idx = tree.add(0, tk as u32, root_logits[tk]);
        meta.insert(idx, Meta { anc: Vec::new(), hidden: root_hidden.clone() });
        frontier.push(idx);
    }

    // --- 3b. deeper levels --------------------------------------------------
    for _level in 1..cfg.tree_depth {
        if frontier.is_empty() {
            break;
        }
        frontier.sort_by(|&a, &b| {
            tree.nodes[b]
                .score
                .partial_cmp(&tree.nodes[a].score)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        frontier.truncate(w.min(cfg.tree_top_k));
        let toks: Vec<u32> = frontier.iter().map(|&i| tree.nodes[i].token).collect();
        let mut fts = vec![0f32; w * f3];
        let mut ancs: Vec<Vec<usize>> = Vec::with_capacity(frontier.len());
        let mut pos: Vec<i32> = Vec::with_capacity(w);
        for (s, &ti) in frontier.iter().enumerate() {
            let m = &meta[&ti];
            tile3(&mut fts[s * f3..(s + 1) * f3], &m.hidden);
            ancs.push(m.anc.clone());
            pos.push((root_pos + tree.nodes[ti].depth) as i32);
        }
        for _ in frontier.len()..w {
            pos.push(*pos.last().unwrap_or(&(root_pos as i32)));
        }
        let (out, offsets) = draft.level(&toks, &fts, &pos, &ancs)?;

        let parents = std::mem::take(&mut frontier);
        for (s, &pi) in parents.iter().enumerate() {
            let lp = log_softmax(out.logits(s));
            let hid = out.hidden(s);
            let mut panc = meta[&pi].anc.clone();
            panc.push(offsets[s]);
            for &tk in top_k(&lp, 2).iter() {
                let idx = tree.add(pi, tk as u32, lp[tk]);
                meta.insert(idx, Meta { anc: panc.clone(), hidden: hid.to_vec() });
                frontier.push(idx);
            }
        }
    }

    Ok(DraftRound { tree: tree.prune_top(cfg.tree_size), bonus_hidden: root_hidden })
}
