//! Decoding engines: the SpecPV generator and the paper's baselines,
//! behind a common `Engine` trait.
//!
//! | engine      | draft                     | verification            |
//! |-------------|---------------------------|-------------------------|
//! | `ar`        | —                         | full KV, 1 token/step   |
//! | `spec_full` | EAGLE-3 tree              | full KV (EAGLE3-YARN)   |
//! | `spec_pv`   | EAGLE-3 tree              | partial KV + Refresh    |
//! | `triforce`  | independent tiny LM chain | full KV                 |
//! | `tokenswift`| Medusa heads              | full KV                 |

pub mod ar;
pub mod eagle;
pub mod session;
pub mod spec_full;
pub mod spec_pv;
pub mod tokenswift;
pub mod triforce;

use anyhow::Result;

use crate::config::{Config, EngineKind};
use crate::metrics::GenStats;
use crate::runtime::Runtime;

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
}

impl GenRequest {
    pub fn greedy(prompt: Vec<u32>, max_new: usize) -> GenRequest {
        GenRequest { prompt, max_new, temperature: 0.0, seed: 0 }
    }
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub tokens: Vec<u32>,
    pub stats: GenStats,
}

impl GenResult {
    pub fn text(&self) -> String {
        crate::tokenizer::decode(&self.tokens)
    }
}

/// A decoding engine bound to a runtime + config.
pub trait Engine {
    fn kind(&self) -> EngineKind;

    /// Run one full generation (prefill + decode loop).
    fn generate(&mut self, rt: &Runtime, req: &GenRequest) -> Result<GenResult>;
}

/// Construct the engine selected by the config.
pub fn build(cfg: &Config) -> Box<dyn Engine> {
    match cfg.engine {
        EngineKind::Autoregressive => Box::new(ar::ArEngine::new(cfg.clone())),
        EngineKind::SpecFull => Box::new(spec_full::SpecFullEngine::new(cfg.clone())),
        EngineKind::SpecPv => Box::new(spec_pv::SpecPvEngine::new(cfg.clone())),
        EngineKind::TriForce => Box::new(triforce::TriForceEngine::new(cfg.clone())),
        EngineKind::TokenSwift => Box::new(tokenswift::TokenSwiftEngine::new(cfg.clone())),
    }
}

/// Convenience used by harnesses: build + generate in one call.
pub fn generate_with(
    cfg: &Config,
    rt: &Runtime,
    req: &GenRequest,
) -> Result<GenResult> {
    build(cfg).generate(rt, req)
}
