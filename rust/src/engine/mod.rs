//! Decoding engines: the SpecPV generator and the paper's baselines,
//! behind a common step-resumable session API.
//!
//! | engine      | draft                     | verification            |
//! |-------------|---------------------------|-------------------------|
//! | `ar`        | —                         | full KV, 1 token/step   |
//! | `spec_full` | EAGLE-3 tree              | full KV (EAGLE3-YARN)   |
//! | `spec_pv`   | EAGLE-3 tree              | partial KV + Refresh    |
//! | `triforce`  | independent tiny LM chain | full KV                 |
//! | `tokenswift`| Medusa heads              | full KV                 |
//!
//! An [`Engine`] is a stateless constructor: `start()` runs prefill and
//! returns a live [`EngineSession`] whose `step()` advances exactly one
//! draft→verify→accept round (one decode token for `ar`). The coordinator
//! interleaves `step()` calls across many sessions (continuous batching);
//! `generate_with` is the run-to-completion convenience built on top.
//!
//! Engines are generic over `&dyn Backend` (the typed kernel-op API), so
//! the same decode algorithms run on the PJRT artifact player and the
//! pure-Rust reference executor.

pub mod ar;
pub mod eagle;
pub mod plan;
pub mod scripted;
pub mod session;
pub mod spec_full;
pub mod spec_pv;
pub mod tokenswift;
pub mod triforce;

use anyhow::{bail, Result};

use crate::backend::{pick_bucket, Backend, StateBuf, StateKind};

pub use self::plan::{Drive, KernelPlan};
use crate::config::{Config, EngineKind};
use crate::kvstore::{KvCtx, KvStore, PagedState};
use crate::metrics::GenStats;
use crate::policy::{PolicyDirective, PolicyState, SpecObservation};
use crate::model::bucket_need;
use crate::tokenizer::is_eos;

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
}

impl GenRequest {
    pub fn greedy(prompt: Vec<u32>, max_new: usize) -> GenRequest {
        GenRequest { prompt, max_new, temperature: 0.0, seed: 0 }
    }
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub tokens: Vec<u32>,
    pub stats: GenStats,
}

impl GenResult {
    pub fn text(&self) -> String {
        crate::tokenizer::decode(&self.tokens)
    }
}

/// What one scheduler-visible `step()` produced.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    /// tokens newly available since the previous `step()` (includes the
    /// prefill bonus token on the first step)
    pub new_tokens: Vec<u32>,
    /// the session reached `max_new` or emitted EOS
    pub finished: bool,
}

/// A portable, host-side snapshot of a live session between steps —
/// everything needed to rebuild it on a *different* backend instance
/// (shard failover, DESIGN.md §15) and continue byte-identically: the
/// exported device state, the KV-cache cursors, the emitted tokens and
/// the sampling RNG state. Plain host data (`Send`), so it crosses shard
/// threads where sessions and backends cannot.
#[derive(Debug, Clone)]
pub struct SessionCheckpoint {
    pub engine: EngineKind,
    /// tokens emitted up to the checkpoint (already clipped to `max_new`)
    pub emitted: Vec<u32>,
    /// scheduler steps taken up to the checkpoint
    pub steps: usize,
    /// exported device state: model-size key, bucket and flat payload
    /// (the same layout `Backend::export_state` produces)
    pub size: String,
    pub bucket: usize,
    pub data: Vec<f32>,
    pub extra: Vec<f32>,
    /// KV-cache cursors (`cache::FullCache`) at the checkpoint
    pub committed: usize,
    pub pending: Vec<usize>,
    /// sampling RNG state (exact stream continuation for temperature > 0)
    pub rng: u64,
    /// adaptive-policy controller state at the checkpoint (DESIGN.md
    /// §16): a failed-over session resumes with its learned draft depth
    /// and drift instead of resetting to defaults. `None` when the
    /// policy layer is off or never observed the session.
    pub policy: Option<PolicyState>,
}

/// Durable checkpoint image magic ("SPVC") + format version.
const DURABLE_MAGIC: u32 = 0x5350_5643;
const DURABLE_VERSION: u32 = 1;

/// Bounded little-endian cursor for [`SessionCheckpoint::decode_durable`].
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() < self.i + n {
            bail!("truncated durable checkpoint ({} bytes)", self.b.len());
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

impl SessionCheckpoint {
    /// Approximate host bytes the snapshot occupies (metrics only).
    pub fn approx_bytes(&self) -> usize {
        (self.data.len() + self.extra.len()) * 4 + self.emitted.len() * 4
    }

    /// Serialize into the crash-consistent on-disk image the durable
    /// checkpoint store persists (DESIGN.md §17): a checksummed JSON
    /// metadata frame followed by the `data`/`extra` state payloads in
    /// the KV spill-page codec (magic/len/checksum validated on decode).
    /// The RNG state is carried as a decimal string — JSON numbers are
    /// f64 and would corrupt a full-range u64.
    pub fn encode_durable(&self) -> Vec<u8> {
        use crate::json::Json;
        let emitted: Vec<Json> = self.emitted.iter().map(|&t| Json::from(t as f64)).collect();
        let pending: Vec<Json> = self.pending.iter().map(|&p| Json::from(p as f64)).collect();
        let mut meta = Json::obj()
            .set("engine", self.engine.to_string())
            .set("steps", self.steps as f64)
            .set("size", self.size.as_str())
            .set("bucket", self.bucket as f64)
            .set("committed", self.committed as f64)
            .set("rng", format!("{}", self.rng))
            .set("emitted", Json::Arr(emitted))
            .set("pending", Json::Arr(pending));
        if let Some(p) = &self.policy {
            meta = meta.set("policy", p.to_json());
        }
        let meta_bytes = meta.to_string().into_bytes();
        let data_blob = crate::kvstore::pool::encode_f32_blob(&self.data);
        let extra_blob = crate::kvstore::pool::encode_f32_blob(&self.extra);
        let mut out =
            Vec::with_capacity(28 + meta_bytes.len() + data_blob.len() + extra_blob.len());
        out.extend_from_slice(&DURABLE_MAGIC.to_le_bytes());
        out.extend_from_slice(&DURABLE_VERSION.to_le_bytes());
        out.extend_from_slice(&(meta_bytes.len() as u32).to_le_bytes());
        out.extend_from_slice(&crate::kvstore::pool::hash_bytes(&meta_bytes).to_le_bytes());
        out.extend_from_slice(&meta_bytes);
        for blob in [&data_blob, &extra_blob] {
            out.extend_from_slice(&(blob.len() as u32).to_le_bytes());
            out.extend_from_slice(blob);
        }
        out
    }

    /// Inverse of [`SessionCheckpoint::encode_durable`]. Any truncation
    /// or corruption (bad magic, checksum mismatch, torn payload)
    /// surfaces as a clean error — recovery treats it as "no durable
    /// checkpoint" and regenerates from the journal instead.
    pub fn decode_durable(blob: &[u8]) -> Result<SessionCheckpoint> {
        use crate::json::Json;
        let mut c = Cur { b: blob, i: 0 };
        let magic = c.u32()?;
        if magic != DURABLE_MAGIC {
            bail!("bad durable checkpoint magic {magic:#x}");
        }
        let version = c.u32()?;
        if version != DURABLE_VERSION {
            bail!("unsupported durable checkpoint version {version}");
        }
        let meta_len = c.u32()? as usize;
        let meta_sum = c.u64()?;
        let meta_bytes = c.take(meta_len)?;
        if crate::kvstore::pool::hash_bytes(meta_bytes) != meta_sum {
            bail!("durable checkpoint metadata checksum mismatch");
        }
        let meta = Json::parse(std::str::from_utf8(meta_bytes)?)?;
        let data_len = c.u32()? as usize;
        let data = crate::kvstore::pool::decode_f32_blob(c.take(data_len)?)?;
        let extra_len = c.u32()? as usize;
        let extra = crate::kvstore::pool::decode_f32_blob(c.take(extra_len)?)?;

        let num = |k: &str| -> Result<f64> {
            meta.at(k)?.as_f64().ok_or_else(|| anyhow::anyhow!("checkpoint key '{k}' not a number"))
        };
        let arr = |k: &str| -> Result<Vec<f64>> {
            Ok(meta
                .at(k)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("checkpoint key '{k}' not an array"))?
                .iter()
                .filter_map(|v| v.as_f64())
                .collect())
        };
        let engine: EngineKind = meta
            .at("engine")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("checkpoint engine not a string"))?
            .parse()?;
        let rng: u64 = meta
            .at("rng")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("checkpoint rng not a string"))?
            .parse()
            .map_err(|e| anyhow::anyhow!("checkpoint rng: {e}"))?;
        Ok(SessionCheckpoint {
            engine,
            emitted: arr("emitted")?.into_iter().map(|x| x as u32).collect(),
            steps: num("steps")? as usize,
            size: meta
                .at("size")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("checkpoint size not a string"))?
                .to_string(),
            bucket: num("bucket")? as usize,
            data,
            extra,
            committed: num("committed")? as usize,
            pending: arr("pending")?.into_iter().map(|x| x as usize).collect(),
            rng,
            policy: meta.get("policy").map(PolicyState::from_json),
        })
    }
}

/// A live, step-resumable generation. Created by [`Engine::start`] (which
/// performs prefill and picks the first token); each `step()` runs one
/// draft→verify→accept round; `finish()` packages the result.
pub trait EngineSession {
    fn kind(&self) -> EngineKind;

    /// True once the output is complete; further `step()` calls are no-ops
    /// that only drain unreported tokens.
    fn is_finished(&self) -> bool;

    /// Tokens emitted so far (never exceeds the request's `max_new`).
    fn emitted(&self) -> usize;

    /// Advance one decode round and report newly produced tokens.
    fn step(&mut self) -> Result<StepOutcome>;

    /// Consume the session, yielding the final result. Valid at any point
    /// (cancellation yields the partial output produced so far).
    fn finish(self: Box<Self>) -> GenResult;

    /// Resident device bytes this session's states hold (what the KV
    /// pool's admission accounting charges). 0 for stateless (scripted)
    /// sessions.
    fn state_bytes(&self) -> usize {
        0
    }

    /// Swap-out: park every device state as page-pool block tables and
    /// drop the device buffers. The caller owns the returned tables'
    /// page refs (they survive `park_cold` demotion to int8/disk). The
    /// session keeps its host-side bookkeeping (caches, RNG, output
    /// cursor) and is dormant — `step()` is invalid — until the tables
    /// come back through `resume`. Default: stateless sessions suspend
    /// to nothing.
    fn suspend(&mut self) -> Result<Vec<PagedState>> {
        Ok(Vec::new())
    }

    /// Swap-in: rebuild device states from the block tables produced by
    /// `suspend` (promoted back to RAM first if demoted), after which
    /// `step()` continues byte-identically to an unsuspended run (for
    /// `kv_quant = none`). Consumes the tables — the session frees the
    /// page refs after streaming them back in.
    fn resume(&mut self, states: Vec<PagedState>) -> Result<()> {
        if states.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("session holds no device state to resume")
        }
    }

    /// Snapshot the session between steps for failover
    /// (DESIGN.md §15). `Ok(None)` means "not checkpointable right now"
    /// — mid-step, already finished, or an engine without support (the
    /// default); failover then regenerates from the prompt, which is
    /// equally deterministic, just slower.
    fn checkpoint(&self) -> Result<Option<SessionCheckpoint>> {
        Ok(None)
    }

    // --- policy hooks (adaptive speculation, DESIGN.md §16) -------------

    /// Cumulative speculation counters for the policy layer. `None`
    /// means the session has nothing to report (plain `ar`, foreign
    /// sessions) and the coordinator skips policy tracking for it.
    fn spec_observe(&self) -> Option<SpecObservation> {
        None
    }

    /// Apply a policy directive between steps (the session is always at
    /// a round boundary when the coordinator calls this). Engines clamp
    /// the depth to their own hard limits and ignore overrides that
    /// would break their output contract — losslessness-pinned engines
    /// refuse depth changes at temperature > 0, where a different draft
    /// shape would perturb the sampling RNG stream.
    fn apply_policy(&mut self, _d: &PolicyDirective) {}

    // --- plan/apply protocol (batched execution, DESIGN.md §12) ---------

    /// Advance the step state machine: run host-side work (and
    /// non-batchable backend ops) until the next batchable kernel op is
    /// pending ([`Drive::Pending`]) or the step completes
    /// ([`Drive::Complete`]). The default reports
    /// [`Drive::Unsupported`]; the coordinator then falls back to
    /// `step()` for this session.
    fn drive(&mut self) -> Result<Drive> {
        Ok(Drive::Unsupported)
    }

    /// Move the pending [`KernelPlan`] and the state buffer it targets
    /// out of the session so the coordinator can fuse the op with other
    /// sessions' plans. `None` when nothing is pending. The session is
    /// dormant until [`EngineSession::restore_pending`] hands the
    /// (mutated) state back.
    fn take_pending(&mut self) -> Option<(KernelPlan, StateBuf)> {
        None
    }

    /// Return the state buffer moved out by
    /// [`EngineSession::take_pending`] after the op executed.
    fn restore_pending(&mut self, state: StateBuf) {
        let _ = state;
    }
}

/// A decoding engine bound to a config; `start` binds it to a backend and
/// a request.
pub trait Engine {
    fn kind(&self) -> EngineKind;

    /// Prefill and return a live session positioned after the first
    /// token. `kv` supplies the shared page pool sessions park into on
    /// suspend plus the optional prompt-prefix cache consulted during
    /// prefill ([`KvCtx::disabled`] opts out of both) — see
    /// `crate::kvstore`.
    fn start<'be>(
        &self,
        be: &'be dyn Backend,
        req: &GenRequest,
        kv: &KvCtx,
    ) -> Result<Box<dyn EngineSession + 'be>>;

    /// Rebuild a session from a [`SessionCheckpoint`] taken on another
    /// backend instance, skipping prefill entirely — the checkpoint's
    /// exported state is imported as-is and generation continues
    /// byte-identically from the snapshot point. Engines without support
    /// (the default) report an error; the caller falls back to a fresh
    /// deterministic `start`.
    fn start_from_checkpoint<'be>(
        &self,
        be: &'be dyn Backend,
        req: &GenRequest,
        kv: &KvCtx,
        ck: &SessionCheckpoint,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let _ = (be, req, kv, ck);
        anyhow::bail!("engine {} does not support checkpoint resume", self.kind())
    }
}

/// Predicted resident state bytes of a `(engine, request)` session —
/// mirrors each engine's allocation geometry so the coordinator can gate
/// admission before paying for prefill. Pinned equal to the live
/// session's `state_bytes()` by `rust/tests/kvstore.rs`.
pub fn estimate_state_bytes(
    be: &dyn Backend,
    cfg: &Config,
    kind: EngineKind,
    req: &GenRequest,
) -> usize {
    let consts = be.consts();
    let size = cfg.model_size.as_str();
    let need = bucket_need(req.prompt.len(), req.max_new, consts);
    let Ok(bucket) = pick_bucket(&be.full_buckets(size), need, "full", size) else {
        return 0;
    };
    let sb = |kind: StateKind, sz: &str, b: usize| be.state_bytes(kind, sz, b).unwrap_or(0);
    let mut total = sb(StateKind::Full, size, bucket);
    match kind {
        EngineKind::Autoregressive | EngineKind::TokenSwift => {}
        EngineKind::SpecFull => total += sb(StateKind::Draft, size, bucket),
        EngineKind::SpecPv => {
            total += sb(StateKind::Draft, size, bucket);
            let pneed =
                cfg.specpv.core_tokens(consts.block) + consts.tree_t + cfg.specpv.buffer_cap;
            if let Ok(pb) = pick_bucket(&be.partial_buckets(size), pneed, "partial", size) {
                total += sb(StateKind::Partial, size, pb);
            }
        }
        EngineKind::TriForce => total += sb(StateKind::Tiny, "tiny", consts.tiny_bucket),
    }
    total
}

/// Shared output accounting for sessions: enforces the `max_new` bound as
/// tokens are produced (so overshooting acceptance rounds never skew the
/// reported counters — the truncated tokens are excluded from both the
/// output and `accepted_total`) and tracks the not-yet-reported cursor
/// that `StepOutcome::new_tokens` drains.
#[derive(Debug, Default)]
pub struct SessionOut {
    pub tokens: Vec<u32>,
    pub max_new: usize,
    reported: usize,
    pub done: bool,
}

impl SessionOut {
    pub fn new(max_new: usize) -> SessionOut {
        SessionOut { tokens: Vec::new(), max_new, reported: 0, done: max_new == 0 }
    }

    /// Rebuild the accounting at a checkpoint: `tokens` were already
    /// emitted *and reported* before the snapshot, so a resumed session's
    /// first `outcome()` drains only tokens produced after the resume.
    pub fn resumed(max_new: usize, tokens: Vec<u32>) -> SessionOut {
        let done = max_new == 0
            || tokens.len() >= max_new
            || tokens.last().is_some_and(|&t| is_eos(t));
        let reported = tokens.len();
        SessionOut { tokens, max_new, reported, done }
    }

    /// The prefill bonus token (the first output token of every engine).
    pub fn push_first(&mut self, t: u32) {
        if self.max_new == 0 {
            self.done = true;
            return;
        }
        self.tokens.push(t);
        self.done = self.tokens.len() >= self.max_new || is_eos(t);
    }

    /// Append one round's output: the accepted drafted path followed by
    /// the round's bonus token, clipped to `max_new`. Returns how many
    /// *drafted* tokens were actually kept (the τ numerator contribution).
    pub fn push_round(&mut self, drafted: &[u32], bonus: u32) -> usize {
        let room = self.max_new.saturating_sub(self.tokens.len());
        let kept = drafted.len().min(room);
        self.tokens.extend_from_slice(&drafted[..kept]);
        if self.tokens.len() < self.max_new {
            self.tokens.push(bonus);
        }
        self.done = self.tokens.len() >= self.max_new || is_eos(bonus);
        kept
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Last emitted token (sessions only call this when non-empty).
    pub fn last(&self) -> u32 {
        *self.tokens.last().expect("SessionOut::last on empty output")
    }

    /// Drain the unreported tail into a `StepOutcome`.
    pub fn outcome(&mut self) -> StepOutcome {
        let new_tokens = self.tokens[self.reported..].to_vec();
        self.reported = self.tokens.len();
        StepOutcome { new_tokens, finished: self.done }
    }
}

/// Construct the engine selected by the config.
pub fn build(cfg: &Config) -> Box<dyn Engine> {
    match cfg.engine {
        EngineKind::Autoregressive => Box::new(ar::ArEngine::new(cfg.clone())),
        EngineKind::SpecFull => Box::new(spec_full::SpecFullEngine::new(cfg.clone())),
        EngineKind::SpecPv => Box::new(spec_pv::SpecPvEngine::new(cfg.clone())),
        EngineKind::TriForce => Box::new(triforce::TriForceEngine::new(cfg.clone())),
        EngineKind::TokenSwift => Box::new(tokenswift::TokenSwiftEngine::new(cfg.clone())),
    }
}

/// Creates sessions for the scheduler. The production implementation is
/// [`BackendFactory`]; tests inject [`scripted::ScriptedFactory`] to
/// exercise scheduling without any model behind it.
pub trait SessionFactory<'be> {
    fn start_session(
        &mut self,
        kind: EngineKind,
        req: &GenRequest,
    ) -> Result<Box<dyn EngineSession + 'be>>;

    /// Predicted resident state bytes of the session `start_session`
    /// would build (admission gating; 0 = unknown / stateless).
    fn estimate_bytes(&self, _kind: EngineKind, _req: &GenRequest) -> usize {
        0
    }

    /// Rebuild a session from a failover checkpoint instead of running
    /// prefill. Factories without support report an error and the
    /// scheduler falls back to `start_session` (deterministic
    /// regeneration from the prompt).
    fn start_from_checkpoint(
        &mut self,
        kind: EngineKind,
        req: &GenRequest,
        ck: &SessionCheckpoint,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let _ = (kind, req, ck);
        anyhow::bail!("session factory does not support checkpoint resume")
    }
}

/// Session factory over a real backend: builds the engine named by `kind`
/// (with the base config's geometry) and starts it, threading the shared
/// KV context (page pool + optional prompt-prefix cache) into every
/// session.
pub struct BackendFactory<'be> {
    be: &'be dyn Backend,
    base: Config,
    kv: KvCtx,
}

impl<'be> BackendFactory<'be> {
    pub fn new(be: &'be dyn Backend, base: Config) -> BackendFactory<'be> {
        BackendFactory { be, base, kv: KvCtx::disabled() }
    }

    /// Attach a KV context (shared page pool + optional prefix cache).
    pub fn with_kv(mut self, kv: KvCtx) -> BackendFactory<'be> {
        self.kv = kv;
        self
    }

    /// Attach a shared prompt-prefix cache (the factory's pool becomes
    /// the store's pool).
    pub fn with_prefix(self, store: KvStore) -> BackendFactory<'be> {
        self.with_kv(KvCtx::with_prefix(store))
    }
}

impl<'be> SessionFactory<'be> for BackendFactory<'be> {
    fn start_session(
        &mut self,
        kind: EngineKind,
        req: &GenRequest,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let mut cfg = self.base.clone();
        cfg.engine = kind;
        build(&cfg).start(self.be, req, &self.kv)
    }

    fn estimate_bytes(&self, kind: EngineKind, req: &GenRequest) -> usize {
        estimate_state_bytes(self.be, &self.base, kind, req)
    }

    fn start_from_checkpoint(
        &mut self,
        kind: EngineKind,
        req: &GenRequest,
        ck: &SessionCheckpoint,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let mut cfg = self.base.clone();
        cfg.engine = kind;
        build(&cfg).start_from_checkpoint(self.be, req, &self.kv, ck)
    }
}

/// Convenience used by harnesses: start → step loop → finish. Produces
/// byte-identical tokens to the pre-session monolithic decode loops.
pub fn generate_with(
    cfg: &Config,
    be: &dyn Backend,
    req: &GenRequest,
) -> Result<GenResult> {
    generate_with_store(cfg, be, req, None)
}

/// [`generate_with`] consulting (and feeding) a prompt-prefix cache.
/// Output is byte-identical with or without the store.
pub fn generate_with_store(
    cfg: &Config,
    be: &dyn Backend,
    req: &GenRequest,
    prefix: Option<&KvStore>,
) -> Result<GenResult> {
    let kv = match prefix {
        Some(st) => KvCtx::with_prefix(st.clone()),
        None => KvCtx::disabled(),
    };
    let mut session = build(cfg).start(be, req, &kv)?;
    while !session.is_finished() {
        session.step()?;
    }
    Ok(session.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_out_clips_overshoot() {
        let mut o = SessionOut::new(5);
        o.push_first(65);
        assert!(!o.done);
        // round accepts 3 drafted + bonus: only 4 slots remain
        let kept = o.push_round(&[66, 67, 68], 69);
        assert_eq!(kept, 3);
        assert_eq!(o.tokens, vec![65, 66, 67, 68, 69]);
        assert!(o.done);
        // overshooting round: 2 slots of drafted kept, bonus dropped
        let mut o = SessionOut::new(3);
        o.push_first(65);
        let kept = o.push_round(&[66, 67, 68], 69);
        assert_eq!(kept, 2);
        assert_eq!(o.tokens, vec![65, 66, 67]);
        assert!(o.done);
    }

    #[test]
    fn session_out_eos_finishes() {
        let mut o = SessionOut::new(100);
        o.push_first(65);
        o.push_round(&[], crate::tokenizer::EOS);
        assert!(o.done);
        assert_eq!(o.len(), 2);
    }

    #[test]
    fn session_out_outcome_drains() {
        let mut o = SessionOut::new(10);
        o.push_first(65);
        o.push_round(&[66], 67);
        let s = o.outcome();
        assert_eq!(s.new_tokens, vec![65, 66, 67]);
        assert!(!s.finished);
        let s2 = o.outcome();
        assert!(s2.new_tokens.is_empty());
    }

    #[test]
    fn session_out_resumed_reports_only_new_tokens() {
        let mut o = SessionOut::resumed(10, vec![65, 66, 67]);
        assert!(!o.done);
        assert_eq!(o.len(), 3);
        // nothing unreported at the checkpoint …
        assert!(o.outcome().new_tokens.is_empty());
        // … and only post-resume tokens drain afterwards
        o.push_round(&[68], 69);
        assert_eq!(o.outcome().new_tokens, vec![68, 69]);
        // resuming at the cap (or past an EOS) is already done
        assert!(SessionOut::resumed(3, vec![65, 66, 67]).done);
        assert!(SessionOut::resumed(9, vec![65, crate::tokenizer::EOS]).done);
    }

    #[test]
    fn session_out_zero_max_new() {
        let mut o = SessionOut::new(0);
        o.push_first(65);
        assert!(o.done);
        assert!(o.is_empty());
    }
}
