//! EAGLE3-YARN baseline: EAGLE-3 tree drafting with **full-KV**
//! verification every step (the paper's strongest lossless baseline,
//! Tables 1/3 row 3). Also the shared implementation of the "Full" mode
//! rounds inside SpecPV. One `step()` = one draft→verify→accept round,
//! exposed as a plan/apply machine (DESIGN.md §12): every draft-expand
//! level and the tree verification surface as batchable kernel plans so
//! concurrent sessions fuse per-layer matmuls.

use anyhow::{bail, Result};

use crate::backend::{Backend, StateBuf, StateKind};
use crate::config::Config;
use crate::kvstore::{KvCtx, KvPool, PagedState};
use crate::manifest::Consts;
use crate::metrics::GenStats;
use crate::model::{bucket_need, ReadOut};
use crate::offload::OffloadSim;
use crate::sampling::pick_token;
use crate::tree::Tree;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::eagle::{DraftInputs, DraftTreeRun};
use super::plan::{exec_single, Drive, KernelPlan, OpClass};
use super::session::{DraftSession, TargetSession};
use super::{Engine, EngineSession, GenRequest, GenResult, SessionOut, StepOutcome};
use crate::policy::{PolicyDirective, SpecObservation};

pub struct SpecFullEngine {
    cfg: Config,
}

impl SpecFullEngine {
    pub fn new(cfg: Config) -> SpecFullEngine {
        SpecFullEngine { cfg }
    }
}

/// Pick the target's committed token at every tree node.
pub fn tree_picks(
    tree: &Tree,
    read: &ReadOut,
    row_off: usize,
    temperature: f32,
    rng: &mut Rng,
) -> Vec<u32> {
    (0..tree.len())
        .map(|i| pick_token(read.logits(row_off + i), temperature, rng))
        .collect()
}

/// One round's acceptance bookkeeping shared by the spec engines.
pub struct RoundAccept {
    /// accepted drafted tokens in path order
    pub path_tokens: Vec<u32>,
    /// flat-tree indices of the accepted path
    pub path_idx: Vec<usize>,
    /// the new bonus token
    pub bonus: u32,
    /// flat index of the deepest accepted node (0 = root)
    pub deepest: usize,
}

pub fn accept_round(tree: &Tree, picks: &[u32]) -> RoundAccept {
    let (path_idx, bonus) = tree.greedy_accept(picks);
    let path_tokens = path_idx.iter().map(|&i| tree.nodes[i].token).collect();
    let deepest = *path_idx.last().unwrap_or(&0);
    RoundAccept { path_tokens, path_idx, bonus, deepest }
}

/// Where a spec_full step is between `drive()` calls.
enum Phase {
    Idle,
    /// drafting: the run plans draft-expand ops one at a time
    Draft(Box<DraftTreeRun>),
    /// tree verification in flight
    Verify { tree: Tree, flat_n: usize },
}

pub struct SpecFullSession<'rt> {
    be: &'rt dyn Backend,
    target: TargetSession<'rt>,
    draft: DraftSession<'rt>,
    pool: KvPool,
    out: SessionOut,
    /// the current round's tree root (last emitted by the target itself)
    bonus: u32,
    /// previous round's accepted path: (token, fused target feature)
    chain: Vec<(u32, Vec<f32>)>,
    /// recycled draft hidden of the bonus's predecessor
    prev_hidden: Vec<f32>,
    rng: Rng,
    stats: GenStats,
    cfg: Config,
    consts: Consts,
    prompt_len: usize,
    temperature: f32,
    phase: Phase,
    pending: Option<KernelPlan>,
    sw: Stopwatch,
    /// draft tokens offered to verification (policy layer, DESIGN.md §16)
    proposed: u64,
}

impl Engine for SpecFullEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::SpecFull
    }

    fn start<'be>(
        &self,
        be: &'be dyn Backend,
        req: &GenRequest,
        kv: &KvCtx,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let consts = be.consts().clone();
        let need = bucket_need(req.prompt.len(), req.max_new, &consts);
        let mut target = TargetSession::new(
            be,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;
        let mut draft = DraftSession::new(be, &self.cfg.model_size, target.bucket)?;

        let mut sw = Stopwatch::new();
        let (logits, _feat_last) = target.prefill(&req.prompt, Some(&mut draft), kv)?;
        stats.prefill_secs = sw.lap();

        let bonus = pick_token(&logits, req.temperature, &mut rng);
        let mut out = SessionOut::new(req.max_new);
        out.push_first(bonus);
        // first round: no catch-up chain; the bonus's predecessor hidden
        // is the draft hidden of the last prompt token (pass-1 convention)
        let prev_hidden =
            draft.read_hidden_row((req.prompt.len() - 1) % consts.chunk)?;

        Ok(Box::new(SpecFullSession {
            be,
            target,
            draft,
            pool: kv.pool.clone(),
            out,
            bonus,
            chain: Vec::new(),
            prev_hidden,
            rng,
            stats,
            cfg: self.cfg.clone(),
            consts,
            prompt_len: req.prompt.len(),
            temperature: req.temperature,
            phase: Phase::Idle,
            pending: None,
            sw: Stopwatch::new(),
            proposed: 0,
        }))
    }
}

impl SpecFullSession<'_> {
    /// Which state buffer the pending plan mutates.
    fn pending_state(&mut self, class: OpClass) -> &mut StateBuf {
        match class {
            OpClass::DraftExpand => &mut self.draft.state,
            _ => &mut self.target.state,
        }
    }
}

impl EngineSession for SpecFullSession<'_> {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::SpecFull
    }

    fn is_finished(&self) -> bool {
        self.out.done
    }

    fn emitted(&self) -> usize {
        self.out.len()
    }

    fn step(&mut self) -> Result<StepOutcome> {
        loop {
            match self.drive()? {
                Drive::Complete(o) => return Ok(o),
                Drive::Pending => {
                    let plan = self.pending.take().expect("pending plan after Drive::Pending");
                    let be = self.be;
                    exec_single(be, &plan, self.pending_state(plan.class))?;
                    self.pending = Some(plan);
                }
                Drive::Unsupported => {
                    unreachable!("spec_full sessions implement the protocol")
                }
            }
        }
    }

    fn drive(&mut self) -> Result<Drive> {
        loop {
            let phase = std::mem::replace(&mut self.phase, Phase::Idle);
            match phase {
                Phase::Idle => {
                    if self.out.done {
                        return Ok(Drive::Complete(self.out.outcome()));
                    }
                    self.sw = Stopwatch::new();
                    let chain_start =
                        self.prompt_len + self.out.len() - 1 - self.chain.len();
                    let run = DraftTreeRun::new(
                        &self.cfg,
                        DraftInputs {
                            chain: std::mem::take(&mut self.chain),
                            bonus: self.bonus,
                            chain_start_pos: chain_start,
                            prev_hidden: std::mem::take(&mut self.prev_hidden),
                        },
                    );
                    self.phase = Phase::Draft(Box::new(run));
                }
                Phase::Draft(mut run) => match run.next_op(&mut self.draft)? {
                    Some(plan) => {
                        self.pending = Some(plan);
                        self.phase = Phase::Draft(run);
                        return Ok(Drive::Pending);
                    }
                    None => {
                        let round = run.finish();
                        self.prev_hidden = round.bonus_hidden;
                        self.stats.draft_secs += self.sw.lap();
                        let tree = round.tree;
                        let flat = tree.flatten(self.consts.tree_t);
                        let root_pos = self.prompt_len + self.out.len() - 1;
                        let plan = self.target.plan_verify_tree(&flat, root_pos)?;
                        self.pending = Some(plan);
                        self.phase = Phase::Verify { tree, flat_n: flat.n };
                        return Ok(Drive::Pending);
                    }
                },
                Phase::Verify { tree, flat_n } => {
                    self.pending = None;
                    let read = self.target.finish_verify_tree(flat_n)?;
                    self.stats.verify_secs += self.sw.lap();

                    // --- accept -----------------------------------------
                    let picks =
                        tree_picks(&tree, &read, 0, self.temperature, &mut self.rng);
                    let acc = accept_round(&tree, &picks);
                    if std::env::var("SPECPV_DEBUG").is_ok() && self.stats.verify_steps < 10 {
                        let kids: Vec<u32> = tree
                            .children(0)
                            .iter()
                            .map(|&c| tree.nodes[c].token)
                            .collect();
                        eprintln!(
                            "round {}: root={:?} target_pick={:?} draft_kids={:?} hit={}",
                            self.stats.verify_steps,
                            char::from_u32(self.bonus).unwrap_or('?'),
                            char::from_u32(picks[0]).unwrap_or('?'),
                            kids.iter()
                                .map(|&k| char::from_u32(k).unwrap_or('?'))
                                .collect::<Vec<_>>(),
                            kids.contains(&picks[0]),
                        );
                    }
                    self.stats.verify_steps += 1;
                    self.proposed += self.cfg.tree_depth as u64;
                    let kept = self.out.push_round(&acc.path_tokens, acc.bonus);
                    self.stats.accepted_total += kept;
                    self.stats.full_steps += 1;

                    // pending compaction rows: root + accepted path
                    let mut rows = vec![0usize];
                    rows.extend(&acc.path_idx);
                    self.target.cache.set_pending(rows, self.consts.prev_window())?;

                    // next round's draft chain: accepted path tokens with
                    // their target features; bonus feature = feature of
                    // deepest node
                    self.chain = acc
                        .path_idx
                        .iter()
                        .map(|&i| (tree.nodes[i].token, read.feats(i).to_vec()))
                        .collect();
                    self.bonus = acc.bonus;
                    self.stats.other_secs += self.sw.lap();

                    return Ok(Drive::Complete(self.out.outcome()));
                }
            }
        }
    }

    fn take_pending(&mut self) -> Option<(KernelPlan, StateBuf)> {
        let plan = self.pending.take()?;
        let state =
            std::mem::replace(self.pending_state(plan.class), StateBuf::nil());
        Some((plan, state))
    }

    fn restore_pending(&mut self, state: StateBuf) {
        match &self.phase {
            Phase::Draft(_) => self.draft.state = state,
            _ => self.target.state = state,
        }
    }

    fn spec_observe(&self) -> Option<SpecObservation> {
        Some(SpecObservation {
            proposed: self.proposed,
            committed: self.stats.accepted_total as u64,
            verify_steps: self.stats.verify_steps as u64,
            full_steps: self.stats.full_steps as u64,
            partial_steps: 0,
            refresh_steps: 0,
            context_len: self.prompt_len + self.out.len(),
            depth: self.cfg.tree_depth,
            pv_len: 0,
        })
    }

    fn apply_policy(&mut self, d: &PolicyDirective) {
        // losslessness contract: at temperature > 0 verification draws
        // one RNG sample per tree node, so a different draft shape would
        // shift the sampling stream and change output — keep it pinned.
        // At greedy the picks are pure argmax and the depth only decides
        // how far ahead each round reaches, never which tokens commit.
        if self.temperature > 0.0 {
            return;
        }
        if let Some(depth) = d.draft_depth {
            // next round's catch-up chain is the accepted path (≤ depth
            // tokens) plus the bonus — it must fit the compiled draft
            // chain window
            let cap = self.consts.draft_w.saturating_sub(2).max(1);
            self.cfg.tree_depth = depth.clamp(1, cap);
        }
    }

    fn finish(self: Box<Self>) -> GenResult {
        let SpecFullSession { target, out, mut stats, .. } = *self;
        stats.decode_secs = stats.draft_secs + stats.verify_secs + stats.other_secs;
        stats.new_tokens = out.tokens.len();
        stats.offload_secs = target.offload.secs;
        GenResult { tokens: out.tokens, stats }
    }

    fn state_bytes(&self) -> usize {
        self.target.state_bytes() + self.draft.state_bytes()
    }

    fn suspend(&mut self) -> Result<Vec<PagedState>> {
        let states = vec![self.target.park(&self.pool)?, self.draft.park(&self.pool)?];
        self.target.drop_state();
        self.draft.drop_state();
        Ok(states)
    }

    fn resume(&mut self, states: Vec<PagedState>) -> Result<()> {
        let (mut full, mut draft) = (false, false);
        for ps in &states {
            match ps.kind {
                StateKind::Full => {
                    self.target.restore_paged(&self.pool, ps)?;
                    full = true;
                }
                StateKind::Draft => {
                    self.draft.restore_paged(&self.pool, ps)?;
                    draft = true;
                }
                k => bail!("unexpected {k:?} block table for a spec_full session"),
            }
        }
        if !(full && draft) {
            bail!("spec_full resume needs full + draft block tables");
        }
        for ps in &states {
            self.pool.free_state(ps);
        }
        Ok(())
    }
}
