//! EAGLE3-YARN baseline: EAGLE-3 tree drafting with **full-KV**
//! verification every step (the paper's strongest lossless baseline,
//! Tables 1/3 row 3). Also the shared implementation of the "Full" mode
//! rounds inside SpecPV.

use anyhow::Result;

use crate::config::Config;
use crate::metrics::GenStats;
use crate::model::{bucket_need, ReadOut};
use crate::offload::OffloadSim;
use crate::runtime::Runtime;
use crate::sampling::pick_token;
use crate::tokenizer::is_eos;
use crate::tree::Tree;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::eagle::{draft_tree, DraftInputs};
use super::session::{DraftSession, TargetSession};
use super::{Engine, GenRequest, GenResult};

pub struct SpecFullEngine {
    cfg: Config,
}

impl SpecFullEngine {
    pub fn new(cfg: Config) -> SpecFullEngine {
        SpecFullEngine { cfg }
    }
}

/// Pick the target's committed token at every tree node.
pub fn tree_picks(
    tree: &Tree,
    read: &ReadOut,
    row_off: usize,
    temperature: f32,
    rng: &mut Rng,
) -> Vec<u32> {
    (0..tree.len())
        .map(|i| pick_token(read.logits(row_off + i), temperature, rng))
        .collect()
}

/// One round's acceptance bookkeeping shared by the spec engines.
pub struct RoundAccept {
    /// accepted drafted tokens in path order
    pub path_tokens: Vec<u32>,
    /// flat-tree indices of the accepted path
    pub path_idx: Vec<usize>,
    /// the new bonus token
    pub bonus: u32,
    /// flat index of the deepest accepted node (0 = root)
    pub deepest: usize,
}

pub fn accept_round(tree: &Tree, picks: &[u32]) -> RoundAccept {
    let (path_idx, bonus) = tree.greedy_accept(picks);
    let path_tokens = path_idx.iter().map(|&i| tree.nodes[i].token).collect();
    let deepest = *path_idx.last().unwrap_or(&0);
    RoundAccept { path_tokens, path_idx, bonus, deepest }
}

impl Engine for SpecFullEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::SpecFull
    }

    fn generate(&mut self, rt: &Runtime, req: &GenRequest) -> Result<GenResult> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let consts = rt.manifest.consts.clone();
        let need = bucket_need(req.prompt.len(), req.max_new, &consts);
        let mut target = TargetSession::new(
            rt,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;
        let mut draft = DraftSession::new(rt, &self.cfg.model_size, target.bucket)?;

        let mut sw = Stopwatch::new();
        let (logits, _feat_last) = target.prefill(&req.prompt, Some(&mut draft))?;
        stats.prefill_secs = sw.lap();

        let mut out: Vec<u32> = Vec::new();
        let mut bonus = pick_token(&logits, req.temperature, &mut rng);
        out.push(bonus);
        // first round: no catch-up chain; the bonus's predecessor hidden
        // is the draft hidden of the last prompt token (pass-1 convention)
        let mut chain: Vec<(u32, Vec<f32>)> = Vec::new();
        let mut prev_hidden =
            draft.read_hidden_row((req.prompt.len() - 1) % consts.chunk)?;

        while out.len() < req.max_new && !is_eos(bonus) {
            // --- draft ----------------------------------------------------
            let chain_start =
                req.prompt.len() + out.len() - 1 - chain.len();
            let round = draft_tree(
                &mut draft,
                &self.cfg,
                &DraftInputs {
                    chain: std::mem::take(&mut chain),
                    bonus,
                    chain_start_pos: chain_start,
                    prev_hidden: std::mem::take(&mut prev_hidden),
                },
            )?;
            let tree = round.tree;
            prev_hidden = round.bonus_hidden;
            stats.draft_secs += sw.lap();

            // --- verify ---------------------------------------------------
            let flat = tree.flatten(consts.tree_t);
            let root_pos = req.prompt.len() + out.len() - 1;
            let read = target.verify_tree(&flat, root_pos)?;
            stats.verify_secs += sw.lap();

            // --- accept ---------------------------------------------------
            let picks = tree_picks(&tree, &read, 0, req.temperature, &mut rng);
            let acc = accept_round(&tree, &picks);
            if std::env::var("SPECPV_DEBUG").is_ok() && stats.verify_steps < 10 {
                let kids: Vec<u32> = tree.children(0).iter().map(|&c| tree.nodes[c].token).collect();
                eprintln!(
                    "round {}: root={:?} target_pick={:?} draft_kids={:?} hit={}",
                    stats.verify_steps,
                    char::from_u32(bonus).unwrap_or('?'),
                    char::from_u32(picks[0]).unwrap_or('?'),
                    kids.iter().map(|&k| char::from_u32(k).unwrap_or('?')).collect::<Vec<_>>(),
                    kids.contains(&picks[0]),
                );
            }
            stats.verify_steps += 1;
            stats.accepted_total += acc.path_tokens.len();
            stats.full_steps += 1;

            out.extend(&acc.path_tokens);
            out.push(acc.bonus);

            // pending compaction rows: root + accepted path
            let mut rows = vec![0usize];
            rows.extend(&acc.path_idx);
            target.cache.set_pending(rows, consts.prev_window())?;

            // next round's draft chain: accepted path tokens with their
            // target features; bonus feature = feature of deepest node
            chain = acc
                .path_idx
                .iter()
                .map(|&i| (tree.nodes[i].token, read.feats(i).to_vec()))
                .collect();
            bonus = acc.bonus;
            stats.other_secs += sw.lap();
        }
        out.truncate(req.max_new); // multi-token acceptance can overshoot
        stats.decode_secs = stats.draft_secs + stats.verify_secs + stats.other_secs;
        stats.new_tokens = out.len();
        stats.offload_secs = target.offload.secs;
        Ok(GenResult { tokens: out, stats })
    }
}
