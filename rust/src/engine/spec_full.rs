//! EAGLE3-YARN baseline: EAGLE-3 tree drafting with **full-KV**
//! verification every step (the paper's strongest lossless baseline,
//! Tables 1/3 row 3). Also the shared implementation of the "Full" mode
//! rounds inside SpecPV. One `step()` = one draft→verify→accept round.

use anyhow::{bail, Result};

use crate::backend::{Backend, StateKind, StateSnapshot};
use crate::config::Config;
use crate::kvstore::KvStore;
use crate::manifest::Consts;
use crate::metrics::GenStats;
use crate::model::{bucket_need, ReadOut};
use crate::offload::OffloadSim;
use crate::sampling::pick_token;
use crate::tree::Tree;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::eagle::{draft_tree, DraftInputs};
use super::session::{DraftSession, TargetSession};
use super::{Engine, EngineSession, GenRequest, GenResult, SessionOut, StepOutcome};

pub struct SpecFullEngine {
    cfg: Config,
}

impl SpecFullEngine {
    pub fn new(cfg: Config) -> SpecFullEngine {
        SpecFullEngine { cfg }
    }
}

/// Pick the target's committed token at every tree node.
pub fn tree_picks(
    tree: &Tree,
    read: &ReadOut,
    row_off: usize,
    temperature: f32,
    rng: &mut Rng,
) -> Vec<u32> {
    (0..tree.len())
        .map(|i| pick_token(read.logits(row_off + i), temperature, rng))
        .collect()
}

/// One round's acceptance bookkeeping shared by the spec engines.
pub struct RoundAccept {
    /// accepted drafted tokens in path order
    pub path_tokens: Vec<u32>,
    /// flat-tree indices of the accepted path
    pub path_idx: Vec<usize>,
    /// the new bonus token
    pub bonus: u32,
    /// flat index of the deepest accepted node (0 = root)
    pub deepest: usize,
}

pub fn accept_round(tree: &Tree, picks: &[u32]) -> RoundAccept {
    let (path_idx, bonus) = tree.greedy_accept(picks);
    let path_tokens = path_idx.iter().map(|&i| tree.nodes[i].token).collect();
    let deepest = *path_idx.last().unwrap_or(&0);
    RoundAccept { path_tokens, path_idx, bonus, deepest }
}

pub struct SpecFullSession<'rt> {
    target: TargetSession<'rt>,
    draft: DraftSession<'rt>,
    out: SessionOut,
    /// the current round's tree root (last emitted by the target itself)
    bonus: u32,
    /// previous round's accepted path: (token, fused target feature)
    chain: Vec<(u32, Vec<f32>)>,
    /// recycled draft hidden of the bonus's predecessor
    prev_hidden: Vec<f32>,
    rng: Rng,
    stats: GenStats,
    cfg: Config,
    consts: Consts,
    prompt_len: usize,
    temperature: f32,
}

impl Engine for SpecFullEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::SpecFull
    }

    fn start<'be>(
        &self,
        be: &'be dyn Backend,
        req: &GenRequest,
        prefix: Option<&KvStore>,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let consts = be.consts().clone();
        let need = bucket_need(req.prompt.len(), req.max_new, &consts);
        let mut target = TargetSession::new(
            be,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;
        let mut draft = DraftSession::new(be, &self.cfg.model_size, target.bucket)?;

        let mut sw = Stopwatch::new();
        let (logits, _feat_last) = target.prefill(&req.prompt, Some(&mut draft), prefix)?;
        stats.prefill_secs = sw.lap();

        let bonus = pick_token(&logits, req.temperature, &mut rng);
        let mut out = SessionOut::new(req.max_new);
        out.push_first(bonus);
        // first round: no catch-up chain; the bonus's predecessor hidden
        // is the draft hidden of the last prompt token (pass-1 convention)
        let prev_hidden =
            draft.read_hidden_row((req.prompt.len() - 1) % consts.chunk)?;

        Ok(Box::new(SpecFullSession {
            target,
            draft,
            out,
            bonus,
            chain: Vec::new(),
            prev_hidden,
            rng,
            stats,
            cfg: self.cfg.clone(),
            consts,
            prompt_len: req.prompt.len(),
            temperature: req.temperature,
        }))
    }
}

impl EngineSession for SpecFullSession<'_> {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::SpecFull
    }

    fn is_finished(&self) -> bool {
        self.out.done
    }

    fn emitted(&self) -> usize {
        self.out.len()
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if self.out.done {
            return Ok(self.out.outcome());
        }
        let mut sw = Stopwatch::new();

        // --- draft ----------------------------------------------------
        let chain_start = self.prompt_len + self.out.len() - 1 - self.chain.len();
        let round = draft_tree(
            &mut self.draft,
            &self.cfg,
            &DraftInputs {
                chain: std::mem::take(&mut self.chain),
                bonus: self.bonus,
                chain_start_pos: chain_start,
                prev_hidden: std::mem::take(&mut self.prev_hidden),
            },
        )?;
        let tree = round.tree;
        self.prev_hidden = round.bonus_hidden;
        self.stats.draft_secs += sw.lap();

        // --- verify ---------------------------------------------------
        let flat = tree.flatten(self.consts.tree_t);
        let root_pos = self.prompt_len + self.out.len() - 1;
        let read = self.target.verify_tree(&flat, root_pos)?;
        self.stats.verify_secs += sw.lap();

        // --- accept ---------------------------------------------------
        let picks = tree_picks(&tree, &read, 0, self.temperature, &mut self.rng);
        let acc = accept_round(&tree, &picks);
        if std::env::var("SPECPV_DEBUG").is_ok() && self.stats.verify_steps < 10 {
            let kids: Vec<u32> =
                tree.children(0).iter().map(|&c| tree.nodes[c].token).collect();
            eprintln!(
                "round {}: root={:?} target_pick={:?} draft_kids={:?} hit={}",
                self.stats.verify_steps,
                char::from_u32(self.bonus).unwrap_or('?'),
                char::from_u32(picks[0]).unwrap_or('?'),
                kids.iter()
                    .map(|&k| char::from_u32(k).unwrap_or('?'))
                    .collect::<Vec<_>>(),
                kids.contains(&picks[0]),
            );
        }
        self.stats.verify_steps += 1;
        let kept = self.out.push_round(&acc.path_tokens, acc.bonus);
        self.stats.accepted_total += kept;
        self.stats.full_steps += 1;

        // pending compaction rows: root + accepted path
        let mut rows = vec![0usize];
        rows.extend(&acc.path_idx);
        self.target.cache.set_pending(rows, self.consts.prev_window())?;

        // next round's draft chain: accepted path tokens with their
        // target features; bonus feature = feature of deepest node
        self.chain = acc
            .path_idx
            .iter()
            .map(|&i| (tree.nodes[i].token, read.feats(i).to_vec()))
            .collect();
        self.bonus = acc.bonus;
        self.stats.other_secs += sw.lap();

        Ok(self.out.outcome())
    }

    fn finish(self: Box<Self>) -> GenResult {
        let SpecFullSession { target, out, mut stats, .. } = *self;
        stats.decode_secs = stats.draft_secs + stats.verify_secs + stats.other_secs;
        stats.new_tokens = out.tokens.len();
        stats.offload_secs = target.offload.secs;
        GenResult { tokens: out.tokens, stats }
    }

    fn state_bytes(&self) -> usize {
        self.target.state_bytes() + self.draft.state_bytes()
    }

    fn suspend(&mut self) -> Result<Vec<StateSnapshot>> {
        let snaps = vec![self.target.export()?, self.draft.export()?];
        self.target.drop_state();
        self.draft.drop_state();
        Ok(snaps)
    }

    fn resume(&mut self, snaps: Vec<StateSnapshot>) -> Result<()> {
        let (mut full, mut draft) = (false, false);
        for s in &snaps {
            match s.kind {
                StateKind::Full => {
                    self.target.restore(s)?;
                    full = true;
                }
                StateKind::Draft => {
                    self.draft.restore(s)?;
                    draft = true;
                }
                k => bail!("unexpected {k:?} snapshot for a spec_full session"),
            }
        }
        if !(full && draft) {
            bail!("spec_full resume needs full + draft snapshots");
        }
        Ok(())
    }
}
