//! Standard autoregressive decoding — the speedup denominator of every
//! table in the paper (Eq. 4). One `step()` = one decoded token,
//! exposed to the scheduler as a two-phase plan/apply machine (plan the
//! T=1 verify, then consume its logits) so concurrent AR sessions'
//! decode ops can fuse into one batched backend invocation.

use anyhow::Result;

use crate::backend::{Backend, StateBuf, StateKind};
use crate::config::Config;
use crate::kvstore::{KvCtx, KvPool, PagedState};
use crate::metrics::GenStats;
use crate::model::bucket_need;
use crate::offload::OffloadSim;
use crate::sampling::pick_token;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::plan::{exec_single, Drive, KernelPlan};
use super::session::TargetSession;
use super::{Engine, EngineSession, GenRequest, GenResult, SessionOut, StepOutcome};

pub struct ArEngine {
    cfg: Config,
}

impl ArEngine {
    pub fn new(cfg: Config) -> ArEngine {
        ArEngine { cfg }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// between steps; the next drive plans a T=1 verify
    Idle,
    /// the planned verify is executing; the next drive consumes it
    Verify,
}

pub struct ArSession<'rt> {
    be: &'rt dyn Backend,
    target: TargetSession<'rt>,
    pool: KvPool,
    out: SessionOut,
    rng: Rng,
    stats: GenStats,
    prompt_len: usize,
    temperature: f32,
    phase: Phase,
    pending: Option<KernelPlan>,
    sw: Stopwatch,
}

impl Engine for ArEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::Autoregressive
    }

    fn start<'be>(
        &self,
        be: &'be dyn Backend,
        req: &GenRequest,
        kv: &KvCtx,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let need = bucket_need(req.prompt.len(), req.max_new, be.consts());
        let mut target = TargetSession::new(
            be,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;

        let mut sw = Stopwatch::new();
        let (logits, _) = target.prefill(&req.prompt, None, kv)?;
        stats.prefill_secs = sw.lap();

        let mut out = SessionOut::new(req.max_new);
        out.push_first(pick_token(&logits, req.temperature, &mut rng));
        Ok(Box::new(ArSession {
            be,
            target,
            pool: kv.pool.clone(),
            out,
            rng,
            stats,
            prompt_len: req.prompt.len(),
            temperature: req.temperature,
            phase: Phase::Idle,
            pending: None,
            sw: Stopwatch::new(),
        }))
    }
}

impl EngineSession for ArSession<'_> {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::Autoregressive
    }

    fn is_finished(&self) -> bool {
        self.out.done
    }

    fn emitted(&self) -> usize {
        self.out.len()
    }

    fn step(&mut self) -> Result<StepOutcome> {
        loop {
            match self.drive()? {
                Drive::Complete(o) => return Ok(o),
                Drive::Pending => {
                    let plan =
                        self.pending.as_ref().expect("pending plan after Drive::Pending");
                    exec_single(self.be, plan, &mut self.target.state)?;
                }
                Drive::Unsupported => unreachable!("ar sessions implement the protocol"),
            }
        }
    }

    fn drive(&mut self) -> Result<Drive> {
        match self.phase {
            Phase::Idle => {
                if self.out.done {
                    return Ok(Drive::Complete(self.out.outcome()));
                }
                self.sw = Stopwatch::new();
                let pos = self.prompt_len + self.out.len() - 1;
                let plan = self.target.plan_decode_one(self.out.last(), pos)?;
                self.pending = Some(plan);
                self.phase = Phase::Verify;
                Ok(Drive::Pending)
            }
            Phase::Verify => {
                self.pending = None;
                self.phase = Phase::Idle;
                let logits = self.target.finish_decode_one()?;
                let next = pick_token(&logits, self.temperature, &mut self.rng);
                self.out.push_round(&[], next);
                self.stats.verify_steps += 1;
                self.stats.decode_secs += self.sw.lap();
                Ok(Drive::Complete(self.out.outcome()))
            }
        }
    }

    fn take_pending(&mut self) -> Option<(KernelPlan, StateBuf)> {
        let plan = self.pending.take()?;
        let state = std::mem::replace(&mut self.target.state, StateBuf::nil());
        Some((plan, state))
    }

    fn restore_pending(&mut self, state: StateBuf) {
        self.target.state = state;
    }

    fn finish(self: Box<Self>) -> GenResult {
        let ArSession { target, out, mut stats, .. } = *self;
        stats.verify_secs = stats.decode_secs;
        stats.new_tokens = out.tokens.len();
        stats.offload_secs = target.offload.secs;
        GenResult { tokens: out.tokens, stats }
    }

    fn state_bytes(&self) -> usize {
        self.target.state_bytes()
    }

    fn suspend(&mut self) -> Result<Vec<PagedState>> {
        let ps = self.target.park(&self.pool)?;
        self.target.drop_state();
        Ok(vec![ps])
    }

    fn resume(&mut self, states: Vec<PagedState>) -> Result<()> {
        let mut full = false;
        for ps in &states {
            match ps.kind {
                StateKind::Full => {
                    self.target.restore_paged(&self.pool, ps)?;
                    full = true;
                }
                k => anyhow::bail!("unexpected {k:?} block table for an ar session"),
            }
        }
        if !full {
            anyhow::bail!("ar resume needs a full block table");
        }
        for ps in &states {
            self.pool.free_state(ps);
        }
        Ok(())
    }
}
