//! Standard autoregressive decoding — the speedup denominator of every
//! table in the paper (Eq. 4).

use anyhow::Result;

use crate::config::Config;
use crate::metrics::GenStats;
use crate::model::bucket_need;
use crate::offload::OffloadSim;
use crate::runtime::Runtime;
use crate::sampling::pick_token;
use crate::tokenizer::is_eos;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::session::TargetSession;
use super::{Engine, GenRequest, GenResult};

pub struct ArEngine {
    cfg: Config,
}

impl ArEngine {
    pub fn new(cfg: Config) -> ArEngine {
        ArEngine { cfg }
    }
}

impl Engine for ArEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::Autoregressive
    }

    fn generate(&mut self, rt: &Runtime, req: &GenRequest) -> Result<GenResult> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let need = bucket_need(req.prompt.len(), req.max_new, &rt.manifest.consts);
        let mut target = TargetSession::new(
            rt,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;

        let mut sw = Stopwatch::new();
        let (logits, _) = target.prefill(&req.prompt, None)?;
        stats.prefill_secs = sw.lap();

        let mut out: Vec<u32> = Vec::new();
        let mut next = pick_token(&logits, req.temperature, &mut rng);
        out.push(next);
        while out.len() < req.max_new && !is_eos(next) {
            let pos = req.prompt.len() + out.len() - 1;
            let logits = target.decode_one(next, pos)?;
            next = pick_token(&logits, req.temperature, &mut rng);
            out.push(next);
            stats.verify_steps += 1;
        }
        stats.decode_secs = sw.lap();
        stats.verify_secs = stats.decode_secs;
        stats.new_tokens = out.len();
        stats.offload_secs = target.offload.secs;
        Ok(GenResult { tokens: out, stats })
    }
}
