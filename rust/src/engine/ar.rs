//! Standard autoregressive decoding — the speedup denominator of every
//! table in the paper (Eq. 4). One `step()` = one decoded token,
//! exposed to the scheduler as a two-phase plan/apply machine (plan the
//! T=1 verify, then consume its logits) so concurrent AR sessions'
//! decode ops can fuse into one batched backend invocation.

use anyhow::Result;

use crate::backend::{Backend, StateBuf, StateKind, StateSnapshot};
use crate::config::EngineKind;
use crate::config::Config;
use crate::kvstore::{KvCtx, KvPool, PagedState};
use crate::metrics::GenStats;
use crate::model::bucket_need;
use crate::offload::OffloadSim;
use crate::sampling::pick_token;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::plan::{exec_single, Drive, KernelPlan};
use super::session::TargetSession;
use super::{
    Engine, EngineSession, GenRequest, GenResult, SessionCheckpoint, SessionOut, StepOutcome,
};

pub struct ArEngine {
    cfg: Config,
}

impl ArEngine {
    pub fn new(cfg: Config) -> ArEngine {
        ArEngine { cfg }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// between steps; the next drive plans a T=1 verify
    Idle,
    /// the planned verify is executing; the next drive consumes it
    Verify,
}

pub struct ArSession<'rt> {
    be: &'rt dyn Backend,
    target: TargetSession<'rt>,
    pool: KvPool,
    out: SessionOut,
    rng: Rng,
    stats: GenStats,
    prompt_len: usize,
    temperature: f32,
    phase: Phase,
    pending: Option<KernelPlan>,
    sw: Stopwatch,
}

impl Engine for ArEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::Autoregressive
    }

    fn start<'be>(
        &self,
        be: &'be dyn Backend,
        req: &GenRequest,
        kv: &KvCtx,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let need = bucket_need(req.prompt.len(), req.max_new, be.consts());
        let mut target = TargetSession::new(
            be,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;

        let mut sw = Stopwatch::new();
        let (logits, _) = target.prefill(&req.prompt, None, kv)?;
        stats.prefill_secs = sw.lap();

        let mut out = SessionOut::new(req.max_new);
        out.push_first(pick_token(&logits, req.temperature, &mut rng));
        Ok(Box::new(ArSession {
            be,
            target,
            pool: kv.pool.clone(),
            out,
            rng,
            stats,
            prompt_len: req.prompt.len(),
            temperature: req.temperature,
            phase: Phase::Idle,
            pending: None,
            sw: Stopwatch::new(),
        }))
    }

    /// Failover resume (DESIGN.md §15): import the checkpoint's exported
    /// full state into a fresh session on `be` — **no prefill** — and
    /// continue exactly where the snapshot left off. The KV-cache
    /// cursors, emitted tokens and RNG stream are restored verbatim, so
    /// the continuation is byte-identical to the undisturbed run; what a
    /// regenerating failover pays in prompt-length prefill, this path
    /// pays only in a state import.
    fn start_from_checkpoint<'be>(
        &self,
        be: &'be dyn Backend,
        req: &GenRequest,
        kv: &KvCtx,
        ck: &SessionCheckpoint,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        if ck.engine != EngineKind::Autoregressive {
            anyhow::bail!("checkpoint was taken by engine {}, not ar", ck.engine);
        }
        if ck.emitted.is_empty() {
            anyhow::bail!("checkpoint holds no emitted tokens");
        }
        let need = bucket_need(req.prompt.len(), req.max_new, be.consts());
        let mut target = TargetSession::new(
            be,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;
        let snap = StateSnapshot {
            kind: StateKind::Full,
            size: ck.size.clone(),
            bucket: ck.bucket,
            data: ck.data.clone(),
            extra: ck.extra.clone(),
        };
        // restore() validates size/bucket compatibility — a mismatched
        // checkpoint errors out here and the caller regenerates instead
        target.restore(&snap)?;
        target.cache.committed = ck.committed;
        target.cache.pending = ck.pending.clone();
        let stats = GenStats { verify_steps: ck.steps, ..GenStats::default() };
        Ok(Box::new(ArSession {
            be,
            target,
            pool: kv.pool.clone(),
            out: SessionOut::resumed(req.max_new, ck.emitted.clone()),
            rng: Rng::from_state(ck.rng),
            stats,
            prompt_len: req.prompt.len(),
            temperature: req.temperature,
            phase: Phase::Idle,
            pending: None,
            sw: Stopwatch::new(),
        }))
    }
}

impl EngineSession for ArSession<'_> {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::Autoregressive
    }

    fn is_finished(&self) -> bool {
        self.out.done
    }

    fn emitted(&self) -> usize {
        self.out.len()
    }

    fn step(&mut self) -> Result<StepOutcome> {
        loop {
            match self.drive()? {
                Drive::Complete(o) => return Ok(o),
                Drive::Pending => {
                    let plan =
                        self.pending.as_ref().expect("pending plan after Drive::Pending");
                    exec_single(self.be, plan, &mut self.target.state)?;
                }
                Drive::Unsupported => unreachable!("ar sessions implement the protocol"),
            }
        }
    }

    fn drive(&mut self) -> Result<Drive> {
        match self.phase {
            Phase::Idle => {
                if self.out.done {
                    return Ok(Drive::Complete(self.out.outcome()));
                }
                self.sw = Stopwatch::new();
                let pos = self.prompt_len + self.out.len() - 1;
                let plan = self.target.plan_decode_one(self.out.last(), pos)?;
                self.pending = Some(plan);
                self.phase = Phase::Verify;
                Ok(Drive::Pending)
            }
            Phase::Verify => {
                self.pending = None;
                self.phase = Phase::Idle;
                let logits = self.target.finish_decode_one()?;
                let next = pick_token(&logits, self.temperature, &mut self.rng);
                self.out.push_round(&[], next);
                self.stats.verify_steps += 1;
                self.stats.decode_secs += self.sw.lap();
                Ok(Drive::Complete(self.out.outcome()))
            }
        }
    }

    fn checkpoint(&self) -> Result<Option<SessionCheckpoint>> {
        // only between steps: no in-flight plan, and a finished session
        // needs no failover (its terminal line is authoritative)
        if self.phase != Phase::Idle || self.pending.is_some() || self.out.done {
            return Ok(None);
        }
        let snap = self.target.export()?;
        Ok(Some(SessionCheckpoint {
            engine: EngineKind::Autoregressive,
            emitted: self.out.tokens.clone(),
            steps: self.stats.verify_steps,
            size: snap.size,
            bucket: snap.bucket,
            data: snap.data,
            extra: snap.extra,
            committed: self.target.cache.committed,
            pending: self.target.cache.pending.clone(),
            rng: self.rng.state(),
            policy: None,
        }))
    }

    fn take_pending(&mut self) -> Option<(KernelPlan, StateBuf)> {
        let plan = self.pending.take()?;
        let state = std::mem::replace(&mut self.target.state, StateBuf::nil());
        Some((plan, state))
    }

    fn restore_pending(&mut self, state: StateBuf) {
        self.target.state = state;
    }

    fn finish(self: Box<Self>) -> GenResult {
        let ArSession { target, out, mut stats, .. } = *self;
        stats.verify_secs = stats.decode_secs;
        stats.new_tokens = out.tokens.len();
        stats.offload_secs = target.offload.secs;
        GenResult { tokens: out.tokens, stats }
    }

    fn state_bytes(&self) -> usize {
        self.target.state_bytes()
    }

    fn suspend(&mut self) -> Result<Vec<PagedState>> {
        let ps = self.target.park(&self.pool)?;
        self.target.drop_state();
        Ok(vec![ps])
    }

    fn resume(&mut self, states: Vec<PagedState>) -> Result<()> {
        let mut full = false;
        for ps in &states {
            match ps.kind {
                StateKind::Full => {
                    self.target.restore_paged(&self.pool, ps)?;
                    full = true;
                }
                k => anyhow::bail!("unexpected {k:?} block table for an ar session"),
            }
        }
        if !full {
            anyhow::bail!("ar resume needs a full block table");
        }
        for ps in &states {
            self.pool.free_state(ps);
        }
        Ok(())
    }
}
