//! Standard autoregressive decoding — the speedup denominator of every
//! table in the paper (Eq. 4). One `step()` = one decoded token.

use anyhow::{bail, Result};

use crate::backend::{Backend, StateKind, StateSnapshot};
use crate::config::Config;
use crate::kvstore::KvStore;
use crate::metrics::GenStats;
use crate::model::bucket_need;
use crate::offload::OffloadSim;
use crate::sampling::pick_token;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::session::TargetSession;
use super::{Engine, EngineSession, GenRequest, GenResult, SessionOut, StepOutcome};

pub struct ArEngine {
    cfg: Config,
}

impl ArEngine {
    pub fn new(cfg: Config) -> ArEngine {
        ArEngine { cfg }
    }
}

pub struct ArSession<'rt> {
    target: TargetSession<'rt>,
    out: SessionOut,
    rng: Rng,
    stats: GenStats,
    prompt_len: usize,
    temperature: f32,
}

impl Engine for ArEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::Autoregressive
    }

    fn start<'be>(
        &self,
        be: &'be dyn Backend,
        req: &GenRequest,
        prefix: Option<&KvStore>,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let need = bucket_need(req.prompt.len(), req.max_new, be.consts());
        let mut target = TargetSession::new(
            be,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;

        let mut sw = Stopwatch::new();
        let (logits, _) = target.prefill(&req.prompt, None, prefix)?;
        stats.prefill_secs = sw.lap();

        let mut out = SessionOut::new(req.max_new);
        out.push_first(pick_token(&logits, req.temperature, &mut rng));
        Ok(Box::new(ArSession {
            target,
            out,
            rng,
            stats,
            prompt_len: req.prompt.len(),
            temperature: req.temperature,
        }))
    }
}

impl EngineSession for ArSession<'_> {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::Autoregressive
    }

    fn is_finished(&self) -> bool {
        self.out.done
    }

    fn emitted(&self) -> usize {
        self.out.len()
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if !self.out.done {
            let mut sw = Stopwatch::new();
            let pos = self.prompt_len + self.out.len() - 1;
            let logits = self.target.decode_one(self.out.last(), pos)?;
            let next = pick_token(&logits, self.temperature, &mut self.rng);
            self.out.push_round(&[], next);
            self.stats.verify_steps += 1;
            self.stats.decode_secs += sw.lap();
        }
        Ok(self.out.outcome())
    }

    fn finish(self: Box<Self>) -> GenResult {
        let ArSession { target, out, mut stats, .. } = *self;
        stats.verify_secs = stats.decode_secs;
        stats.new_tokens = out.tokens.len();
        stats.offload_secs = target.offload.secs;
        GenResult { tokens: out.tokens, stats }
    }

    fn state_bytes(&self) -> usize {
        self.target.state_bytes()
    }

    fn suspend(&mut self) -> Result<Vec<StateSnapshot>> {
        let snap = self.target.export()?;
        self.target.drop_state();
        Ok(vec![snap])
    }

    fn resume(&mut self, snaps: Vec<StateSnapshot>) -> Result<()> {
        let mut full = false;
        for s in &snaps {
            match s.kind {
                StateKind::Full => {
                    self.target.restore(s)?;
                    full = true;
                }
                k => bail!("unexpected {k:?} snapshot for an ar session"),
            }
        }
        if !full {
            bail!("ar resume needs a full snapshot");
        }
        Ok(())
    }
}
