//! **SpecPV** — self-speculative decoding with partial verification
//! (the paper's contribution; Algorithm 1).
//!
//! Mode machine per decode round (paper Fig. 2 / §3.3):
//! * **Full** — while the context is shorter than the partial-cache core,
//!   verify against the full cache (identical to EAGLE3-full rounds);
//! * **Refresh** — when the partial cache must be (re)built: verify the
//!   accumulated partially-verified chain + the new tree against the
//!   full cache, commit the exact KV, re-score the blocks with the fresh
//!   queries (Eqs. 1–3), gather the new core, clear the buffer;
//! * **Partial** — verify the tree against the partial cache only
//!   (sink ++ retrieval ++ local ++ buffer); accepted tokens accumulate
//!   in the buffer until its cap forces a Refresh.

use anyhow::Result;

use crate::config::Config;
use crate::metrics::GenStats;
use crate::model::bucket_need;
use crate::offload::OffloadSim;
use crate::retrieval::plan_gather;
use crate::runtime::Runtime;
use crate::sampling::pick_token;
use crate::tokenizer::is_eos;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::eagle::{draft_tree, DraftInputs};
use super::session::{DraftSession, PartialSession, TargetSession};
use super::spec_full::{accept_round, tree_picks};
use super::{Engine, GenRequest, GenResult};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Full,
    Partial,
    Refresh,
}

pub struct SpecPvEngine {
    cfg: Config,
}

impl SpecPvEngine {
    pub fn new(cfg: Config) -> SpecPvEngine {
        SpecPvEngine { cfg }
    }
}

impl Engine for SpecPvEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::SpecPv
    }

    fn generate(&mut self, rt: &Runtime, req: &GenRequest) -> Result<GenResult> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let consts = rt.manifest.consts.clone();
        let need = bucket_need(req.prompt.len(), req.max_new, &consts);
        let mut target = TargetSession::new(
            rt,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;
        let mut draft = DraftSession::new(rt, &self.cfg.model_size, target.bucket)?;
        let mut partial = PartialSession::new(rt, &self.cfg.model_size, &self.cfg.specpv)?;
        let nsel = partial.bucket / consts.block;
        let nb = target.bucket / consts.block;

        // available refresh widths for this bucket
        let t_refresh = consts.refresh_t;
        let big_refresh = rt
            .manifest
            .executables
            .contains_key(&crate::model::verify_name(
                &self.cfg.model_size,
                target.bucket,
                consts.big_refresh_t,
            ))
            .then_some(consts.big_refresh_t);

        let mut sw = Stopwatch::new();
        let (logits, _feat_last) = target.prefill(&req.prompt, Some(&mut draft))?;
        stats.prefill_secs = sw.lap();

        let mut out: Vec<u32> = Vec::new();
        let mut bonus = pick_token(&logits, req.temperature, &mut rng);
        out.push(bonus);
        let mut chain: Vec<(u32, Vec<f32>)> = Vec::new();
        let mut prev_hidden =
            draft.read_hidden_row((req.prompt.len() - 1) % consts.chunk)?;
        // pv chain: output tokens not yet in the full cache (buffer
        // residents); the *last* output (current bonus) is excluded — it
        // becomes the next tree's root
        let mut pv: Vec<u32> = Vec::new();

        while out.len() < req.max_new && !is_eos(bonus) {
            // --- draft ----------------------------------------------------
            let chain_start = req.prompt.len() + out.len() - 1 - chain.len();
            let round = draft_tree(
                &mut draft,
                &self.cfg,
                &DraftInputs {
                    chain: std::mem::take(&mut chain),
                    bonus,
                    chain_start_pos: chain_start,
                    prev_hidden: std::mem::take(&mut prev_hidden),
                },
            )?;
            let tree = round.tree;
            prev_hidden = round.bonus_hidden;
            stats.draft_secs += sw.lap();
            let flat = tree.flatten(consts.tree_t);
            let root_pos = req.prompt.len() + out.len() - 1;

            // --- SelectMode (Alg. 1) ---------------------------------------
            let core_needed = self.cfg.specpv.core_tokens(consts.block);
            let mode = if partial.ready()
                && partial.cache.fits(flat.n, consts.prev_max())
            {
                Mode::Partial
            } else if target.cache.effective_len() + pv.len()
                > core_needed.max(2 * consts.block)
            {
                Mode::Refresh
            } else {
                Mode::Full
            };

            let (read, row_off) = match mode {
                Mode::Full => {
                    let r = target.verify_tree(&flat, root_pos)?;
                    (r, 0usize)
                }
                Mode::Partial => {
                    let r = partial.verify_tree(&flat, root_pos)?;
                    (r, 0usize)
                }
                Mode::Refresh => {
                    // how wide a refresh do we need?
                    let width = pv.len() + consts.tree_t;
                    let t_use = if width <= t_refresh {
                        t_refresh
                    } else if let Some(big) = big_refresh {
                        if width <= big {
                            big
                        } else {
                            anyhow::bail!(
                                "pv chain {} exceeds refresh capacity",
                                pv.len()
                            );
                        }
                    } else {
                        anyhow::bail!(
                            "pv chain {} exceeds refresh capacity {t_refresh}",
                            pv.len()
                        );
                    };
                    let chain_pos = req.prompt.len() + out.len() - 1 - pv.len();
                    let r = target.verify_refresh(&pv, chain_pos, &flat, t_use)?;
                    (r, 0usize)
                }
            };
            stats.verify_secs += sw.lap();

            // --- accept -----------------------------------------------------
            // read window is positioned at the tree for all modes
            let picks = tree_picks(&tree, &read, row_off, req.temperature, &mut rng);
            let acc = accept_round(&tree, &picks);
            stats.verify_steps += 1;
            stats.accepted_total += acc.path_tokens.len();

            match mode {
                Mode::Full => {
                    stats.full_steps += 1;
                    let mut rows = vec![0usize];
                    rows.extend(&acc.path_idx);
                    target.cache.set_pending(rows, consts.prev_window())?;
                }
                Mode::Partial => {
                    stats.partial_steps += 1;
                    let mut rows = vec![0usize];
                    rows.extend(&acc.path_idx);
                    partial.cache.set_pending(rows)?;
                    partial.cache.pv_tokens.push(bonus);
                    partial
                        .cache
                        .pv_tokens
                        .extend(&acc.path_tokens);
                    pv.push(bonus);
                    pv.extend(&acc.path_tokens);
                }
                Mode::Refresh => {
                    stats.refresh_steps += 1;
                    // commit: pv chain ++ root ++ accepted path (window-
                    // relative rows)
                    let n_chain = pv.len();
                    let width = if n_chain + consts.tree_t <= t_refresh {
                        t_refresh
                    } else {
                        big_refresh.unwrap()
                    };
                    let mut rows: Vec<usize> = (0..=n_chain).collect();
                    rows.extend(acc.path_idx.iter().map(|&i| n_chain + i));
                    target.commit_now(&rows, width)?;
                    pv.clear();

                    // re-select retrieval blocks with the fresh queries
                    let n_queries =
                        (n_chain + flat.n).min(consts.qrows);
                    let scores = target.score(n_queries)?;
                    let plan = plan_gather(
                        &scores,
                        target.info.n_layer,
                        nb,
                        consts.block,
                        target.cache.committed,
                        nsel,
                        &self.cfg.specpv,
                    );
                    let pstate = target.gather(&plan, partial.bucket)?;
                    partial.install(pstate, plan.core_len);
                }
            }

            out.extend(&acc.path_tokens);
            out.push(acc.bonus);

            chain = acc
                .path_idx
                .iter()
                .map(|&i| (tree.nodes[i].token, read.feats(row_off + i).to_vec()))
                .collect();
            bonus = acc.bonus;
            stats.other_secs += sw.lap();
        }
        out.truncate(req.max_new); // multi-token acceptance can overshoot
        stats.decode_secs = stats.draft_secs + stats.verify_secs + stats.other_secs;
        stats.new_tokens = out.len();
        stats.offload_secs = target.offload.secs;
        Ok(GenResult { tokens: out, stats })
    }
}
