//! **SpecPV** — self-speculative decoding with partial verification
//! (the paper's contribution; Algorithm 1).
//!
//! Mode machine per decode round (paper Fig. 2 / §3.3):
//! * **Full** — while the context is shorter than the partial-cache core,
//!   verify against the full cache (identical to EAGLE3-full rounds);
//! * **Refresh** — when the partial cache must be (re)built: verify the
//!   accumulated partially-verified chain + the new tree against the
//!   full cache, commit the exact KV, re-score the blocks with the fresh
//!   queries (Eqs. 1–3), gather the new core, clear the buffer;
//! * **Partial** — verify the tree against the partial cache only
//!   (sink ++ retrieval ++ local ++ buffer); accepted tokens accumulate
//!   in the buffer until its cap forces a Refresh.
//!
//! The whole mode machine is step-resumable: its loop state (pv chain,
//! bonus, recycled hidden, partial-cache installation) lives in
//! [`SpecPvSession`] fields so the coordinator can interleave rounds of
//! many generations over one runtime. Each round is additionally a
//! plan/apply machine (DESIGN.md §12): draft expands and the
//! full/partial/refresh verification surface as batchable kernel plans;
//! the Refresh tail (commit, score, gather) stays inline — those ops are
//! gather/reduce shaped, not weight-streaming shaped.

use anyhow::{bail, Result};

use crate::backend::{Backend, StateBuf, StateKind};
use crate::config::Config;
use crate::kvstore::{KvCtx, KvPool, PagedState};
use crate::manifest::Consts;
use crate::metrics::GenStats;
use crate::model::{bucket_need, ReadOut};
use crate::offload::OffloadSim;
use crate::retrieval::plan_gather;
use crate::sampling::pick_token;
use crate::tree::Tree;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::eagle::{DraftInputs, DraftTreeRun};
use super::plan::{exec_single, Drive, KernelPlan, OpClass};
use super::session::{DraftSession, PartialSession, TargetSession};
use super::spec_full::{accept_round, tree_picks, RoundAccept};
use super::{Engine, EngineSession, GenRequest, GenResult, SessionOut, StepOutcome};
use crate::policy::{PolicyDirective, SpecObservation};

pub struct SpecPvEngine {
    cfg: Config,
}

impl SpecPvEngine {
    pub fn new(cfg: Config) -> SpecPvEngine {
        SpecPvEngine { cfg }
    }
}

/// Where a SpecPV round is between `drive()` calls.
enum Phase {
    Idle,
    Draft(Box<DraftTreeRun>),
    VerifyFull { tree: Tree, flat_n: usize },
    VerifyPartial { tree: Tree },
    VerifyRefresh { tree: Tree, flat_n: usize, width: usize },
}

pub struct SpecPvSession<'rt> {
    be: &'rt dyn Backend,
    target: TargetSession<'rt>,
    draft: DraftSession<'rt>,
    partial: PartialSession<'rt>,
    pool: KvPool,
    out: SessionOut,
    /// the current round's tree root (last emitted by the target itself)
    bonus: u32,
    /// previous round's accepted path: (token, fused target feature)
    chain: Vec<(u32, Vec<f32>)>,
    /// recycled draft hidden of the bonus's predecessor
    prev_hidden: Vec<f32>,
    /// pv chain: output tokens not yet in the full cache (buffer
    /// residents); the *last* output (current bonus) is excluded — it
    /// becomes the next tree's root
    pv: Vec<u32>,
    rng: Rng,
    stats: GenStats,
    cfg: Config,
    consts: Consts,
    prompt_len: usize,
    temperature: f32,
    /// retrieval-gather geometry (selected / total blocks)
    nsel: usize,
    nb: usize,
    /// compiled refresh widths for this bucket
    t_refresh: usize,
    big_refresh: Option<usize>,
    phase: Phase,
    pending: Option<KernelPlan>,
    sw: Stopwatch,
    /// draft tokens offered to verification (policy layer, DESIGN.md §16)
    proposed: u64,
    /// drift-triggered refresh requested by the policy layer: the next
    /// SelectMode skips the Partial branch so the refresh (or an exact
    /// Full round) runs ahead of the buffer-cap cadence
    refresh_due: bool,
}

impl Engine for SpecPvEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::SpecPv
    }

    fn start<'be>(
        &self,
        be: &'be dyn Backend,
        req: &GenRequest,
        kv: &KvCtx,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let consts = be.consts().clone();
        let need = bucket_need(req.prompt.len(), req.max_new, &consts);
        let mut target = TargetSession::new(
            be,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;
        let mut draft = DraftSession::new(be, &self.cfg.model_size, target.bucket)?;
        let partial = PartialSession::new(be, &self.cfg.model_size, &self.cfg.specpv)?;
        let nsel = partial.bucket / consts.block;
        let nb = target.bucket / consts.block;

        // refresh widths the backend can execute against this bucket: the
        // narrow width is the default, a wider one (when available)
        // absorbs long pv chains (fig6 large-buffer ablation)
        let widths = be.refresh_widths(&self.cfg.model_size, target.bucket);
        let t_refresh = widths.first().copied().unwrap_or(consts.refresh_t);
        let big_refresh = widths.get(1).copied();

        let mut sw = Stopwatch::new();
        let (logits, _feat_last) = target.prefill(&req.prompt, Some(&mut draft), kv)?;
        stats.prefill_secs = sw.lap();

        let bonus = pick_token(&logits, req.temperature, &mut rng);
        let mut out = SessionOut::new(req.max_new);
        out.push_first(bonus);
        let prev_hidden =
            draft.read_hidden_row((req.prompt.len() - 1) % consts.chunk)?;

        Ok(Box::new(SpecPvSession {
            be,
            target,
            draft,
            partial,
            pool: kv.pool.clone(),
            out,
            bonus,
            chain: Vec::new(),
            prev_hidden,
            pv: Vec::new(),
            rng,
            stats,
            cfg: self.cfg.clone(),
            consts,
            prompt_len: req.prompt.len(),
            temperature: req.temperature,
            nsel,
            nb,
            t_refresh,
            big_refresh,
            phase: Phase::Idle,
            pending: None,
            sw: Stopwatch::new(),
            proposed: 0,
            refresh_due: false,
        }))
    }
}

impl SpecPvSession<'_> {
    /// Which state buffer the pending plan mutates.
    fn pending_state(&mut self, class: OpClass) -> &mut StateBuf {
        match class {
            OpClass::DraftExpand => &mut self.draft.state,
            OpClass::VerifyPartial => self
                .partial
                .state
                .as_mut()
                .expect("partial state present for a pending partial verify"),
            _ => &mut self.target.state,
        }
    }

    /// Shared tail of every round: clip + emit the accepted tokens,
    /// rebuild the next round's catch-up chain, lap the stopwatch.
    fn round_tail(&mut self, tree: &Tree, read: &ReadOut, acc: RoundAccept) -> StepOutcome {
        let kept = self.out.push_round(&acc.path_tokens, acc.bonus);
        self.stats.accepted_total += kept;
        self.chain = acc
            .path_idx
            .iter()
            .map(|&i| (tree.nodes[i].token, read.feats(i).to_vec()))
            .collect();
        self.bonus = acc.bonus;
        self.stats.other_secs += self.sw.lap();
        self.out.outcome()
    }
}

impl EngineSession for SpecPvSession<'_> {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::SpecPv
    }

    fn is_finished(&self) -> bool {
        self.out.done
    }

    fn emitted(&self) -> usize {
        self.out.len()
    }

    fn step(&mut self) -> Result<StepOutcome> {
        loop {
            match self.drive()? {
                Drive::Complete(o) => return Ok(o),
                Drive::Pending => {
                    let plan = self.pending.take().expect("pending plan after Drive::Pending");
                    let be = self.be;
                    exec_single(be, &plan, self.pending_state(plan.class))?;
                    self.pending = Some(plan);
                }
                Drive::Unsupported => {
                    unreachable!("spec_pv sessions implement the protocol")
                }
            }
        }
    }

    fn drive(&mut self) -> Result<Drive> {
        loop {
            let phase = std::mem::replace(&mut self.phase, Phase::Idle);
            match phase {
                Phase::Idle => {
                    if self.out.done {
                        return Ok(Drive::Complete(self.out.outcome()));
                    }
                    self.sw = Stopwatch::new();
                    let chain_start =
                        self.prompt_len + self.out.len() - 1 - self.chain.len();
                    let run = DraftTreeRun::new(
                        &self.cfg,
                        DraftInputs {
                            chain: std::mem::take(&mut self.chain),
                            bonus: self.bonus,
                            chain_start_pos: chain_start,
                            prev_hidden: std::mem::take(&mut self.prev_hidden),
                        },
                    );
                    self.phase = Phase::Draft(Box::new(run));
                }
                Phase::Draft(mut run) => match run.next_op(&mut self.draft)? {
                    Some(plan) => {
                        self.pending = Some(plan);
                        self.phase = Phase::Draft(run);
                        return Ok(Drive::Pending);
                    }
                    None => {
                        let round = run.finish();
                        self.prev_hidden = round.bonus_hidden;
                        self.stats.draft_secs += self.sw.lap();
                        let tree = round.tree;
                        let flat = tree.flatten(self.consts.tree_t);
                        let root_pos = self.prompt_len + self.out.len() - 1;

                        // --- SelectMode (Alg. 1) ------------------------
                        let core_needed =
                            self.cfg.specpv.core_tokens(self.consts.block);
                        if !self.refresh_due
                            && self.partial.ready()
                            && self.partial.cache.fits(flat.n, self.consts.prev_max())
                        {
                            let plan = self.partial.plan_verify_tree(&flat, root_pos)?;
                            self.pending = Some(plan);
                            self.phase = Phase::VerifyPartial { tree };
                        } else if self.target.cache.effective_len() + self.pv.len()
                            > core_needed.max(2 * self.consts.block)
                        {
                            // how wide a refresh do we need?
                            let width = self.pv.len() + self.consts.tree_t;
                            let t_use = if width <= self.t_refresh {
                                self.t_refresh
                            } else if let Some(big) = self.big_refresh {
                                if width <= big {
                                    big
                                } else {
                                    bail!(
                                        "pv chain {} exceeds refresh capacity",
                                        self.pv.len()
                                    );
                                }
                            } else {
                                bail!(
                                    "pv chain {} exceeds refresh capacity {}",
                                    self.pv.len(),
                                    self.t_refresh
                                );
                            };
                            let chain_pos =
                                self.prompt_len + self.out.len() - 1 - self.pv.len();
                            let plan = self.target.plan_verify_refresh(
                                &self.pv, chain_pos, &flat, t_use,
                            )?;
                            self.pending = Some(plan);
                            self.phase =
                                Phase::VerifyRefresh { tree, flat_n: flat.n, width: t_use };
                        } else {
                            let plan = self.target.plan_verify_tree(&flat, root_pos)?;
                            self.pending = Some(plan);
                            self.phase = Phase::VerifyFull { tree, flat_n: flat.n };
                        }
                        return Ok(Drive::Pending);
                    }
                },
                Phase::VerifyFull { tree, flat_n } => {
                    self.pending = None;
                    let read = self.target.finish_verify_tree(flat_n)?;
                    self.stats.verify_secs += self.sw.lap();
                    let picks =
                        tree_picks(&tree, &read, 0, self.temperature, &mut self.rng);
                    let acc = accept_round(&tree, &picks);
                    self.stats.verify_steps += 1;
                    self.proposed += self.cfg.tree_depth as u64;
                    self.stats.full_steps += 1;
                    let mut rows = vec![0usize];
                    rows.extend(&acc.path_idx);
                    self.target.cache.set_pending(rows, self.consts.prev_window())?;
                    return Ok(Drive::Complete(self.round_tail(&tree, &read, acc)));
                }
                Phase::VerifyPartial { tree } => {
                    self.pending = None;
                    let read = self.partial.finish_verify_tree()?;
                    self.stats.verify_secs += self.sw.lap();
                    let picks =
                        tree_picks(&tree, &read, 0, self.temperature, &mut self.rng);
                    let acc = accept_round(&tree, &picks);
                    self.stats.verify_steps += 1;
                    self.proposed += self.cfg.tree_depth as u64;
                    self.stats.partial_steps += 1;
                    let mut rows = vec![0usize];
                    rows.extend(&acc.path_idx);
                    self.partial.cache.set_pending(rows, self.consts.prev_window())?;
                    self.partial.cache.pv_tokens.push(self.bonus);
                    self.partial.cache.pv_tokens.extend(&acc.path_tokens);
                    self.pv.push(self.bonus);
                    self.pv.extend(&acc.path_tokens);
                    return Ok(Drive::Complete(self.round_tail(&tree, &read, acc)));
                }
                Phase::VerifyRefresh { tree, flat_n, width } => {
                    self.pending = None;
                    let n_chain = self.pv.len();
                    let read = self.target.finish_verify_refresh(n_chain, flat_n)?;
                    self.stats.verify_secs += self.sw.lap();
                    let picks =
                        tree_picks(&tree, &read, 0, self.temperature, &mut self.rng);
                    let acc = accept_round(&tree, &picks);
                    self.stats.verify_steps += 1;
                    self.proposed += self.cfg.tree_depth as u64;
                    self.stats.refresh_steps += 1;
                    self.refresh_due = false;
                    // commit: pv chain ++ root ++ accepted path (window-
                    // relative rows)
                    let mut rows: Vec<usize> = (0..=n_chain).collect();
                    rows.extend(acc.path_idx.iter().map(|&i| n_chain + i));
                    self.target.commit_now(&rows, width)?;
                    self.pv.clear();

                    // re-select retrieval blocks with the fresh queries
                    let n_queries = (n_chain + flat_n).min(self.consts.qrows);
                    let scores = self.target.score(n_queries)?;
                    let plan = plan_gather(
                        &scores,
                        self.target.info.n_layer,
                        self.nb,
                        self.consts.block,
                        self.target.cache.committed,
                        self.nsel,
                        &self.cfg.specpv,
                    );
                    let pstate = self.target.gather(&plan, self.partial.bucket)?;
                    self.partial.install(pstate, plan.core_len);
                    return Ok(Drive::Complete(self.round_tail(&tree, &read, acc)));
                }
            }
        }
    }

    fn take_pending(&mut self) -> Option<(KernelPlan, StateBuf)> {
        let plan = self.pending.take()?;
        let state = match plan.class {
            OpClass::VerifyPartial => self
                .partial
                .state
                .take()
                .expect("partial state present for a pending partial verify"),
            class => std::mem::replace(self.pending_state(class), StateBuf::nil()),
        };
        Some((plan, state))
    }

    fn restore_pending(&mut self, state: StateBuf) {
        match &self.phase {
            Phase::Draft(_) => self.draft.state = state,
            Phase::VerifyPartial { .. } => self.partial.state = Some(state),
            _ => self.target.state = state,
        }
    }

    fn spec_observe(&self) -> Option<SpecObservation> {
        Some(SpecObservation {
            proposed: self.proposed,
            committed: self.stats.accepted_total as u64,
            verify_steps: self.stats.verify_steps as u64,
            full_steps: self.stats.full_steps as u64,
            partial_steps: self.stats.partial_steps as u64,
            refresh_steps: self.stats.refresh_steps as u64,
            context_len: self.prompt_len + self.out.len(),
            depth: self.cfg.tree_depth,
            pv_len: self.pv.len(),
        })
    }

    fn apply_policy(&mut self, d: &PolicyDirective) {
        // SpecPV is the approximate engine — no losslessness contract to
        // protect; depth adapts at any temperature
        if let Some(depth) = d.draft_depth {
            let cap = self.consts.draft_w.saturating_sub(2).max(1);
            self.cfg.tree_depth = depth.clamp(1, cap);
        }
        if d.force_refresh {
            self.refresh_due = true;
        }
    }

    fn finish(self: Box<Self>) -> GenResult {
        let SpecPvSession { target, out, mut stats, .. } = *self;
        stats.decode_secs = stats.draft_secs + stats.verify_secs + stats.other_secs;
        stats.new_tokens = out.tokens.len();
        stats.offload_secs = target.offload.secs;
        GenResult { tokens: out.tokens, stats }
    }

    fn state_bytes(&self) -> usize {
        self.target.state_bytes() + self.draft.state_bytes() + self.partial.state_bytes()
    }

    fn suspend(&mut self) -> Result<Vec<PagedState>> {
        let mut states = vec![self.target.park(&self.pool)?, self.draft.park(&self.pool)?];
        if let Some(p) = self.partial.park(&self.pool)? {
            states.push(p);
        }
        self.target.drop_state();
        self.draft.drop_state();
        self.partial.drop_state();
        Ok(states)
    }

    fn resume(&mut self, states: Vec<PagedState>) -> Result<()> {
        let (mut full, mut draft) = (false, false);
        for ps in &states {
            match ps.kind {
                StateKind::Full => {
                    self.target.restore_paged(&self.pool, ps)?;
                    full = true;
                }
                StateKind::Draft => {
                    self.draft.restore_paged(&self.pool, ps)?;
                    draft = true;
                }
                // the partial table is present iff a core was installed
                // before the swap; its cache accounting (core length,
                // buffer, pv chain) never left the session
                StateKind::Partial => self.partial.restore_paged(&self.pool, ps)?,
                k => bail!("unexpected {k:?} block table for a spec_pv session"),
            }
        }
        if !(full && draft) {
            bail!("spec_pv resume needs full + draft block tables");
        }
        for ps in &states {
            self.pool.free_state(ps);
        }
        Ok(())
    }
}
