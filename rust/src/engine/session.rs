//! Device-state sessions: thin stateful wrappers that pair a threaded
//! flat-state buffer with its rust-side cache accounting and the typed
//! [`Backend`] kernel-op calls.
//!
//! * [`TargetSession`] — the target model over a full bucket (prefill,
//!   verify/refresh, commit, score, gather, reads)
//! * [`PartialSession`] — the SpecPV partial cache (pverify + reads)
//! * [`DraftSession`] — the EAGLE-3 draft layer (prefill, chain, levels)
//! * [`TinySession`] — the independent TriForce draft LM (streaming ring)
//!
//! Sessions are generic over `&dyn Backend`, so the same draft/verify/
//! accept logic runs against the PJRT artifact player or the pure-Rust
//! reference executor unchanged.

use anyhow::{bail, Result};

use crate::backend::{
    pick_bucket, Backend, CommitOp, DraftPrefillOp, GatherOp, PrefillOp, ReadOp, ScoreOp,
    StateBuf, StateKind, StateSnapshot, TinyForwardOp,
};
use crate::cache::{DraftCache, FullCache, PartialCache};
use crate::config::SpecPvConfig;
use crate::kvstore::{prefix::geom_hash, KvCtx, KvPool, PagedState};
use crate::manifest::{Consts, ModelInfo};
use crate::model::{self, DraftOut, ReadOut};
use crate::offload::OffloadSim;
use crate::retrieval::GatherPlan;
use crate::tokenizer::PAD;
use crate::tree::{chain_mask, FlatTree};

use super::plan::{exec_single, KernelPlan, OpClass};

/// Move a session's state out for an ownership-taking backend op (the
/// field gets a nil placeholder until the op's successor is stored).
fn take(state: &mut StateBuf) -> StateBuf {
    std::mem::replace(state, StateBuf::nil())
}

/// Prefix-cache geometry key for a target prefill: anything that would
/// make a cached snapshot non-reusable must be folded in here.
fn prefix_geom(backend: &str, size: &str, bucket: usize, chunk: usize, with_draft: bool) -> u64 {
    geom_hash(&[
        backend.as_bytes(),
        size.as_bytes(),
        &(bucket as u64).to_le_bytes(),
        &(chunk as u64).to_le_bytes(),
        &[with_draft as u8],
    ])
}

pub struct TargetSession<'a> {
    be: &'a dyn Backend,
    pub size: String,
    pub bucket: usize,
    pub state: StateBuf,
    pub cache: FullCache,
    pub info: ModelInfo,
    pub consts: Consts,
    pub offload: OffloadSim,
}

impl<'a> TargetSession<'a> {
    /// Create a session whose bucket can hold `need` tokens.
    pub fn new(
        be: &'a dyn Backend,
        size: &str,
        need: usize,
        offload: OffloadSim,
    ) -> Result<TargetSession<'a>> {
        let bucket = pick_bucket(&be.full_buckets(size), need, "full", size)?;
        let consts = be.consts().clone();
        let info = be.model(size)?;
        let state = be.alloc_state(StateKind::Full, size, bucket)?;
        Ok(TargetSession {
            be,
            size: size.to_string(),
            bucket,
            state,
            cache: FullCache::new(bucket),
            info,
            consts,
            offload,
        })
    }

    fn kv_bpt(&self) -> usize {
        model::kv_bytes_per_token(&self.info)
    }

    /// Chunked prefill; pairs each chunk with the draft session (when
    /// present) so the draft consumes the chunk's features device-side.
    ///
    /// When the [`KvCtx`] carries a prefix cache, it is consulted first:
    /// the longest cached block table whose prefix matches this prompt
    /// (at a chunk boundary) restores directly — the shared pages are
    /// mapped by refcount bump, no new pages are allocated for the
    /// prefix — and only the tail chunks run, so TTFT for a repeated
    /// long document collapses from O(context) to O(tail). Cold prefills
    /// (and hits that this prompt extends) park a block table at the
    /// last whole-chunk boundary on the way through. Cache hits are
    /// exact — the restored state is byte-identical to recomputing the
    /// prefix.
    ///
    /// Returns (last-token logits, last-token fused features).
    pub fn prefill(
        &mut self,
        tokens: &[u32],
        mut draft: Option<&mut DraftSession<'a>>,
        kv: &KvCtx,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if tokens.is_empty() {
            bail!("empty prompt");
        }
        let c = self.consts.chunk;
        let store = kv.prefix.as_ref().filter(|s| s.enabled());
        let geom = prefix_geom(self.be.name(), &self.size, self.bucket, c, draft.is_some());
        // tokens already present after a prefix-cache restore
        let mut restored = 0usize;
        if let Some(st) = store {
            if let Some((len, states)) = st.lookup_longest(geom, tokens, c) {
                let want = if draft.is_some() { 2 } else { 1 };
                if states.len() == want {
                    self.restore_paged(&kv.pool, &states[0])?;
                    self.cache = FullCache::new(self.bucket);
                    for _ in 0..len / c {
                        self.cache.push_prefill(c)?;
                    }
                    self.offload.touch_full(len, self.kv_bpt());
                    if let Some(d) = draft.as_deref_mut() {
                        d.restore_paged(&kv.pool, &states[1])?;
                        d.cache = DraftCache::new(d.bucket, d.consts.draft_region);
                        for _ in 0..len / c {
                            d.cache.push_prefill(c)?;
                        }
                    }
                    restored = len;
                }
                // the lookup bumped every page's refcount; the restore
                // streamed what it needed, so the shared refs go back
                // either way (count mismatch included)
                for ps in &states {
                    kv.pool.free_state(ps);
                }
            }
        }
        // snapshot boundary: the last whole-chunk prefix that still
        // leaves a tail, so the final-row read always has a freshly
        // computed chunk behind it
        let boundary = ((tokens.len() - 1) / c) * c;
        let mut last_real = 0usize;
        for (ci, chunk) in tokens.chunks(c).enumerate() {
            let r = chunk.len();
            let base = ci * c;
            if base + r <= restored {
                continue; // chunk fully covered by the restored prefix
            }
            last_real = r;
            let mut toks = vec![PAD as i32; c];
            for (i, &t) in chunk.iter().enumerate() {
                toks[i] = t as i32;
            }
            let pos: Vec<i32> = (0..c).map(|i| (base + i) as i32).collect();
            let mask = chain_mask(r, c);
            let op = PrefillOp {
                size: &self.size,
                bucket: self.bucket,
                tokens: &toks,
                pos: &pos,
                mask: &mask,
                kv_len: self.cache.committed,
            };
            let state = take(&mut self.state);
            self.state = self.be.prefill(&op, state)?;
            self.offload.touch_full(self.cache.committed + r, self.kv_bpt());
            if let Some(d) = draft.as_deref_mut() {
                d.prefill_chunk(&toks, r, &pos, &self.state)?;
            }
            self.cache.push_prefill(r)?;
            if let Some(st) = store {
                if base + r == boundary && boundary > restored {
                    // gate on an upper bound of the entry size before
                    // exporting: an entry the budget can never hold must
                    // not pay a device→host readback just to be dropped.
                    // Bound = state layouts + the widest lazy-hidden
                    // region a backend may export + the stored prefix,
                    // so it never under-counts what insert() charges.
                    let est = self.state_bytes()
                        + self.consts.chunk * self.info.d_model * 4
                        + draft.as_deref().map(|d| d.state_bytes()).unwrap_or(0)
                        + boundary * 4;
                    if st.accepts(est) {
                        let mut states = vec![self.park(&kv.pool)?];
                        if let Some(d) = draft.as_deref() {
                            states.push(d.park(&kv.pool)?);
                        }
                        st.insert(geom, &tokens[..boundary], states);
                    }
                }
            }
        }
        let (logits, feats) = self.read_last(last_real - 1)?;
        Ok((logits, feats))
    }

    /// Resident device bytes of this session's state (pool accounting).
    pub fn state_bytes(&self) -> usize {
        self.be.state_bytes(StateKind::Full, &self.size, self.bucket).unwrap_or(0)
    }

    /// Host snapshot of the threaded full state (checkpoint / swap-out).
    pub fn export(&self) -> Result<StateSnapshot> {
        self.be.export_state(StateKind::Full, &self.size, self.bucket, &self.state)
    }

    /// Replace the threaded state with an imported snapshot.
    pub fn restore(&mut self, snap: &StateSnapshot) -> Result<()> {
        if snap.kind != StateKind::Full || snap.size != self.size || snap.bucket != self.bucket {
            bail!("snapshot {snap:?} does not match full session {} b{}", self.size, self.bucket);
        }
        self.state = self.be.import_state(snap)?;
        Ok(())
    }

    /// Park the threaded state into a page pool (suspend / prefix-cache
    /// insert). The caller owns the returned block table's page refs.
    pub fn park(&self, pool: &KvPool) -> Result<PagedState> {
        pool.park_state(self.be, StateKind::Full, &self.size, self.bucket, &self.state)
    }

    /// Rebuild the threaded state from a parked block table. Does not
    /// consume the table's page refs — the caller frees them.
    pub fn restore_paged(&mut self, pool: &KvPool, ps: &PagedState) -> Result<()> {
        if ps.kind != StateKind::Full || ps.size != self.size || ps.bucket != self.bucket {
            bail!(
                "paged state {:?}/{}/b{} does not match full session {} b{}",
                ps.kind, ps.size, ps.bucket, self.size, self.bucket
            );
        }
        self.state = pool.unpark_state(self.be, ps)?;
        Ok(())
    }

    /// Drop the device state (swap-out); `restore` re-installs it.
    pub fn drop_state(&mut self) {
        self.state = StateBuf::nil();
    }

    /// The backend this session executes on. The returned reference is
    /// independent of the `&self` borrow, so a caller can execute a plan
    /// against one of this session's state fields.
    pub fn backend(&self) -> &'a dyn Backend {
        self.be
    }

    /// Plan half of [`TargetSession::verify_tree`]: consume the pending
    /// compaction and describe the verification as a batchable
    /// [`KernelPlan`] (DESIGN.md §12).
    pub fn plan_verify_tree(&mut self, flat: &FlatTree, root_pos: usize) -> Result<KernelPlan> {
        let t = self.consts.tree_t;
        let (kv_len, idx, n_prev) = self.cache.take_pending(self.consts.prev_max())?;
        let mut plan = KernelPlan::new(OpClass::VerifyFull, &self.size, self.bucket, t);
        plan.tokens = flat.tokens.clone();
        plan.pos = flat.positions(root_pos);
        plan.mask = flat.mask.clone();
        plan.kv_len = kv_len;
        plan.prev_idx = idx;
        plan.n_prev = n_prev;
        Ok(plan)
    }

    /// Apply half of [`TargetSession::verify_tree`], run after the plan
    /// executed: offload accounting plus the window read.
    pub fn finish_verify_tree(&mut self, n_new: usize) -> Result<ReadOut> {
        self.offload.touch_full(self.cache.committed + n_new, self.kv_bpt());
        self.read_window(0)
    }

    /// Verify a draft tree against the full cache (EAGLE3-full path and
    /// the SpecPV "Full" mode). Applies the pending fused compaction.
    pub fn verify_tree(&mut self, flat: &FlatTree, root_pos: usize) -> Result<ReadOut> {
        let plan = self.plan_verify_tree(flat, root_pos)?;
        exec_single(self.be, &plan, &mut self.state)?;
        self.finish_verify_tree(flat.n)
    }

    /// Plan half of [`TargetSession::decode_one`] (an AR T=1 verify).
    pub fn plan_decode_one(&mut self, token: u32, pos: usize) -> Result<KernelPlan> {
        let (kv_len, idx, n_prev) = self.cache.take_pending(self.consts.prev_max())?;
        let mut plan = KernelPlan::new(OpClass::VerifyFull, &self.size, self.bucket, 1);
        plan.tokens = vec![token as i32];
        plan.pos = vec![pos as i32];
        plan.mask = vec![1.0];
        plan.kv_len = kv_len;
        plan.prev_idx = idx;
        plan.n_prev = n_prev;
        Ok(plan)
    }

    /// Apply half of [`TargetSession::decode_one`]: accounting, the
    /// next step's pending compaction, and the logits read.
    pub fn finish_decode_one(&mut self) -> Result<Vec<f32>> {
        self.offload.touch_full(self.cache.committed + 1, self.kv_bpt());
        self.cache.set_pending(vec![0], self.consts.prev_window())?;
        let (logits, _) = self.read_last(0)?;
        Ok(logits)
    }

    /// AR decode step (T=1): returns the token's logits row.
    pub fn decode_one(&mut self, token: u32, pos: usize) -> Result<Vec<f32>> {
        let plan = self.plan_decode_one(token, pos)?;
        exec_single(self.be, &plan, &mut self.state)?;
        self.finish_decode_one()
    }

    /// Plan half of [`TargetSession::verify_refresh`].
    pub fn plan_verify_refresh(
        &mut self,
        chain: &[u32],
        chain_start_pos: usize,
        flat: &FlatTree,
        t_refresh: usize,
    ) -> Result<KernelPlan> {
        let n_chain = chain.len();
        let t_tree = flat.tokens.len();
        if n_chain + t_tree > t_refresh {
            bail!("refresh overflow: {n_chain}+{t_tree} > {t_refresh}");
        }
        let (kv_len, idx, n_prev) = self.cache.take_pending(self.consts.prev_max())?;

        let mut toks = vec![PAD as i32; t_refresh];
        let mut pos = vec![0i32; t_refresh];
        for (i, &t) in chain.iter().enumerate() {
            toks[i] = t as i32;
            pos[i] = (chain_start_pos + i) as i32;
        }
        let root_pos = chain_start_pos + n_chain;
        let tree_pos = flat.positions(root_pos);
        for i in 0..t_tree {
            toks[n_chain + i] = flat.tokens[i];
            pos[n_chain + i] = tree_pos[i];
        }
        let mut plan =
            KernelPlan::new(OpClass::VerifyFull, &self.size, self.bucket, t_refresh);
        plan.tokens = toks;
        plan.pos = pos;
        plan.mask = crate::tree::refresh_mask(n_chain, flat, t_refresh);
        plan.kv_len = kv_len;
        plan.prev_idx = idx;
        plan.n_prev = n_prev;
        Ok(plan)
    }

    /// Apply half of [`TargetSession::verify_refresh`]: offload
    /// accounting plus the window read positioned at the tree.
    pub fn finish_verify_refresh(&mut self, n_chain: usize, n_new: usize) -> Result<ReadOut> {
        self.offload
            .touch_full(self.cache.committed + n_chain + n_new, self.kv_bpt());
        // window positioned so the tree starts at row 0 when possible
        self.read_window(n_chain)
    }

    /// Refresh verification (SpecPV): a pv chain of `chain` tokens
    /// followed by the draft tree, against the full cache, using the
    /// `t_refresh`-wide step. Returns the read window positioned at the
    /// tree (rows 0.. = chain.len() offset applied).
    pub fn verify_refresh(
        &mut self,
        chain: &[u32],
        chain_start_pos: usize,
        flat: &FlatTree,
        t_refresh: usize,
    ) -> Result<ReadOut> {
        let plan = self.plan_verify_refresh(chain, chain_start_pos, flat, t_refresh)?;
        exec_single(self.be, &plan, &mut self.state)?;
        self.finish_verify_refresh(chain.len(), flat.n)
    }

    /// Standalone commit after a Refresh: keep `rows` (chain + accepted
    /// tree path, window-relative, strictly increasing) of the last step.
    pub fn commit_now(&mut self, rows: &[usize], window: usize) -> Result<()> {
        let mut idx = vec![0i32; window];
        for (j, &r) in rows.iter().enumerate() {
            if r >= window {
                bail!("commit row {r} outside window {window}");
            }
            idx[j] = r as i32;
        }
        let op = CommitOp {
            size: &self.size,
            bucket: self.bucket,
            window,
            idx: &idx,
            n: rows.len(),
            kv_len: self.cache.committed,
        };
        let state = take(&mut self.state);
        self.state = self.be.commit(&op, state)?;
        self.offload.touch_full(self.cache.committed, self.kv_bpt());
        self.cache.commit_now(rows.len())
    }

    /// Retrieval scores over the committed cache using the queries the
    /// last (refresh) verification wrote. Flat `[L, 3, NB]`.
    pub fn score(&mut self, n_queries: usize) -> Result<Vec<f32>> {
        let op = ScoreOp {
            size: &self.size,
            bucket: self.bucket,
            kv_len: self.cache.committed,
            n_queries,
        };
        let out = self.be.score(&op, &self.state)?;
        self.offload.touch_full(self.cache.committed, self.kv_bpt());
        Ok(out)
    }

    /// Assemble a fresh partial state from a gather plan.
    pub fn gather(&mut self, plan: &GatherPlan, p_bucket: usize) -> Result<StateBuf> {
        let nsel = plan.block_idx[0].len();
        let mut idx = Vec::with_capacity(self.info.n_layer * nsel);
        for l in &plan.block_idx {
            idx.extend_from_slice(l);
        }
        let op = GatherOp {
            size: &self.size,
            bucket: self.bucket,
            p_bucket,
            block_idx: &idx,
        };
        let out = self.be.refresh_gather(&op, &self.state)?;
        self.offload.touch_full(self.cache.committed, self.kv_bpt());
        Ok(out)
    }

    /// Logits+feats window of `qrows` rows starting at `start`.
    pub fn read_window(&self, start: usize) -> Result<ReadOut> {
        let data = self.be.read_logits(
            &ReadOp::FullWindow { size: &self.size, bucket: self.bucket, start },
            &self.state,
        )?;
        ReadOut::new(
            data,
            self.consts.qrows,
            self.info.vocab,
            3 * self.info.d_model,
        )
    }

    /// Single row logits+feats at `idx` (prefill tail).
    pub fn read_last(&self, idx: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut data = self.be.read_logits(
            &ReadOp::LastRow { size: &self.size, bucket: self.bucket, idx },
            &self.state,
        )?;
        // split the download in place instead of copying both halves
        let feats = data.split_off(self.info.vocab);
        Ok((data, feats))
    }
}

/// SpecPV partial-cache session.
pub struct PartialSession<'a> {
    be: &'a dyn Backend,
    pub size: String,
    pub bucket: usize,
    pub state: Option<StateBuf>,
    pub cache: PartialCache,
    pub info: ModelInfo,
    pub consts: Consts,
}

impl<'a> PartialSession<'a> {
    pub fn new(
        be: &'a dyn Backend,
        size: &str,
        cfg: &SpecPvConfig,
    ) -> Result<PartialSession<'a>> {
        let consts = be.consts().clone();
        let need = cfg.core_tokens(consts.block) + consts.tree_t + cfg.buffer_cap;
        let bucket = pick_bucket(&be.partial_buckets(size), need, "partial", size)?;
        Ok(PartialSession {
            be,
            size: size.to_string(),
            bucket,
            state: None,
            cache: PartialCache::new(bucket, cfg.buffer_cap),
            info: be.model(size)?,
            consts,
        })
    }

    /// Install a freshly gathered core.
    pub fn install(&mut self, state: StateBuf, core_len: usize) {
        self.state = Some(state);
        self.cache.refresh(core_len);
    }

    pub fn ready(&self) -> bool {
        self.state.is_some()
    }

    /// Resident device bytes of this session's state. The partial bucket
    /// capacity counts whether or not a core is installed yet — admission
    /// must reserve the peak footprint, not the warm-up one.
    pub fn state_bytes(&self) -> usize {
        self.be.state_bytes(StateKind::Partial, &self.size, self.bucket).unwrap_or(0)
    }

    /// Host snapshot of the partial state (None before the first gather).
    pub fn export(&self) -> Result<Option<StateSnapshot>> {
        match &self.state {
            Some(s) => Ok(Some(self.be.export_state(
                StateKind::Partial,
                &self.size,
                self.bucket,
                s,
            )?)),
            None => Ok(None),
        }
    }

    /// Re-install an exported partial state (cache accounting is kept by
    /// the session object across a swap, so only the buffer moves).
    pub fn restore(&mut self, snap: &StateSnapshot) -> Result<()> {
        if snap.kind != StateKind::Partial
            || snap.size != self.size
            || snap.bucket != self.bucket
        {
            bail!(
                "snapshot {snap:?} does not match partial session {} p{}",
                self.size,
                self.bucket
            );
        }
        self.state = Some(self.be.import_state(snap)?);
        Ok(())
    }

    /// Park the partial state as pool pages (None before the first
    /// gather — a suspended pre-refresh session has nothing to park).
    pub fn park(&self, pool: &KvPool) -> Result<Option<PagedState>> {
        match &self.state {
            Some(s) => Ok(Some(pool.park_state(
                self.be,
                StateKind::Partial,
                &self.size,
                self.bucket,
                s,
            )?)),
            None => Ok(None),
        }
    }

    /// Rebuild the partial state from a parked block table (cache
    /// accounting lives on the session object and survives the swap).
    pub fn restore_paged(&mut self, pool: &KvPool, ps: &PagedState) -> Result<()> {
        if ps.kind != StateKind::Partial || ps.size != self.size || ps.bucket != self.bucket {
            bail!(
                "paged state {:?}/{}/b{} does not match partial session {} p{}",
                ps.kind, ps.size, ps.bucket, self.size, self.bucket
            );
        }
        self.state = Some(pool.unpark_state(self.be, ps)?);
        Ok(())
    }

    /// Drop the device state (swap-out); `restore` re-installs it.
    pub fn drop_state(&mut self) {
        self.state = None;
    }

    /// The backend this session executes on (see
    /// [`TargetSession::backend`]).
    pub fn backend(&self) -> &'a dyn Backend {
        self.be
    }

    /// Plan half of [`PartialSession::verify_tree`].
    pub fn plan_verify_tree(&mut self, flat: &FlatTree, root_pos: usize) -> Result<KernelPlan> {
        if self.state.is_none() {
            bail!("partial cache not initialised");
        }
        let t = self.consts.tree_t;
        let (kv_len, idx, n_prev) = self.cache.take_pending(self.consts.prev_max())?;
        let mut plan = KernelPlan::new(OpClass::VerifyPartial, &self.size, self.bucket, t);
        plan.tokens = flat.tokens.clone();
        plan.pos = flat.positions(root_pos);
        plan.mask = flat.mask.clone();
        plan.kv_len = kv_len;
        plan.prev_idx = idx;
        plan.n_prev = n_prev;
        Ok(plan)
    }

    /// Apply half of [`PartialSession::verify_tree`]: the tree-rows read.
    pub fn finish_verify_tree(&mut self) -> Result<ReadOut> {
        let t = self.consts.tree_t;
        let data = self.be.read_logits(
            &ReadOp::Partial { size: &self.size, bucket: self.bucket },
            self.state.as_ref().expect("partial state present after verify"),
        )?;
        ReadOut::new(data, t, self.info.vocab, 3 * self.info.d_model)
    }

    /// Partial verification of a draft tree (paper §3.2). Same op shape
    /// as the full verify, small bucket.
    pub fn verify_tree(&mut self, flat: &FlatTree, root_pos: usize) -> Result<ReadOut> {
        let plan = self.plan_verify_tree(flat, root_pos)?;
        exec_single(
            self.be,
            &plan,
            self.state.as_mut().expect("presence checked by plan_verify_tree"),
        )?;
        self.finish_verify_tree()
    }
}

/// EAGLE-3 draft session (one decoder layer, own bucket).
pub struct DraftSession<'a> {
    be: &'a dyn Backend,
    pub size: String,
    pub bucket: usize,
    pub state: StateBuf,
    pub cache: DraftCache,
    pub info: ModelInfo,
    pub consts: Consts,
}

impl<'a> DraftSession<'a> {
    pub fn new(be: &'a dyn Backend, size: &str, bucket: usize) -> Result<DraftSession<'a>> {
        let consts = be.consts().clone();
        let state = be.alloc_state(StateKind::Draft, size, bucket)?;
        Ok(DraftSession {
            be,
            size: size.to_string(),
            bucket,
            state,
            cache: DraftCache::new(bucket, consts.draft_region),
            info: be.model(size)?,
            consts,
        })
    }

    /// Consume one target prefill chunk's features (device-side).
    pub fn prefill_chunk(
        &mut self,
        toks: &[i32],
        real: usize,
        pos: &[i32],
        target_state: &StateBuf,
    ) -> Result<()> {
        let c = self.consts.chunk;
        let mask = chain_mask(real, c);
        let op = DraftPrefillOp {
            size: &self.size,
            bucket: self.bucket,
            tokens: toks,
            pos,
            mask: &mask,
            kv_len: self.cache.committed,
            write_pos: self.cache.committed,
        };
        let state = take(&mut self.state);
        self.state = self.be.draft_prefill(&op, target_state, state)?;
        self.cache.push_prefill(real)
    }

    /// Resident device bytes of this session's state (pool accounting).
    pub fn state_bytes(&self) -> usize {
        self.be.state_bytes(StateKind::Draft, &self.size, self.bucket).unwrap_or(0)
    }

    /// Host snapshot of the draft state (checkpoint / swap-out).
    pub fn export(&self) -> Result<StateSnapshot> {
        self.be.export_state(StateKind::Draft, &self.size, self.bucket, &self.state)
    }

    /// Replace the threaded state with an imported snapshot.
    pub fn restore(&mut self, snap: &StateSnapshot) -> Result<()> {
        if snap.kind != StateKind::Draft || snap.size != self.size || snap.bucket != self.bucket {
            bail!("snapshot {snap:?} does not match draft session {} b{}", self.size, self.bucket);
        }
        self.state = self.be.import_state(snap)?;
        Ok(())
    }

    /// Park the draft state into a page pool (suspend / prefix-cache
    /// insert).
    pub fn park(&self, pool: &KvPool) -> Result<PagedState> {
        pool.park_state(self.be, StateKind::Draft, &self.size, self.bucket, &self.state)
    }

    /// Rebuild the draft state from a parked block table.
    pub fn restore_paged(&mut self, pool: &KvPool, ps: &PagedState) -> Result<()> {
        if ps.kind != StateKind::Draft || ps.size != self.size || ps.bucket != self.bucket {
            bail!(
                "paged state {:?}/{}/b{} does not match draft session {} b{}",
                ps.kind, ps.size, ps.bucket, self.size, self.bucket
            );
        }
        self.state = pool.unpark_state(self.be, ps)?;
        Ok(())
    }

    /// Drop the device state (swap-out); `restore` re-installs it.
    pub fn drop_state(&mut self) {
        self.state = StateBuf::nil();
    }

    /// Hidden state of prefill-chunk row `idx` (the recycled feature for
    /// the first draft after prefill).
    pub fn read_hidden_row(&self, idx: usize) -> Result<Vec<f32>> {
        self.be.read_logits(
            &ReadOp::DraftHiddenRow { size: &self.size, bucket: self.bucket, idx },
            &self.state,
        )
    }

    /// The backend this session executes on (see
    /// [`TargetSession::backend`]).
    pub fn backend(&self) -> &'a dyn Backend {
        self.be
    }

    /// Describe one W-slot draft step as a batchable [`KernelPlan`].
    fn plan_step(
        &mut self,
        tokens: &[u32],
        feats: &[f32],
        pos: &[i32],
        mask: &[f32],
        write_pos: usize,
    ) -> KernelPlan {
        let w = self.consts.draft_w;
        let mut toks = vec![PAD as i32; w];
        for (i, &t) in tokens.iter().enumerate() {
            toks[i] = t as i32;
        }
        let mut plan = KernelPlan::new(OpClass::DraftExpand, &self.size, self.bucket, w);
        plan.tokens = toks;
        plan.feats = feats.to_vec();
        plan.pos = pos.to_vec();
        plan.mask = mask.to_vec();
        plan.kv_len = self.cache.committed;
        plan.write_pos = write_pos;
        plan
    }

    /// Read the W draft rows the last expand produced.
    fn read_step(&mut self) -> Result<DraftOut> {
        let w = self.consts.draft_w;
        let data = self.be.read_logits(
            &ReadOp::Draft { size: &self.size, bucket: self.bucket },
            &self.state,
        )?;
        DraftOut::new(data, w, self.info.vocab, self.info.d_model)
    }

    fn step(
        &mut self,
        tokens: &[u32],
        feats: &[f32],
        pos: &[i32],
        mask: &[f32],
        write_pos: usize,
    ) -> Result<DraftOut> {
        let plan = self.plan_step(tokens, feats, pos, mask, write_pos);
        exec_single(self.be, &plan, &mut self.state)?;
        self.read_step()
    }

    /// Plan half of [`DraftSession::chain`]; returns the plan plus the
    /// chain length to hand back to [`DraftSession::finish_chain`].
    pub fn plan_chain(
        &mut self,
        tokens: &[u32],
        feats: &[f32],
        start_pos: usize,
    ) -> Result<(KernelPlan, usize)> {
        let w = self.consts.draft_w;
        let n = tokens.len();
        if n == 0 || n > w {
            bail!("chain length {n} outside 1..={w}");
        }
        let region = self.consts.draft_region;
        // chain mask within the region: token i sees region cols 0..=i
        let mut mask = vec![0f32; w * region];
        for i in 0..w {
            for j in 0..=i.min(region - 1) {
                mask[i * region + j] = 1.0;
            }
        }
        let pos: Vec<i32> = (0..w).map(|i| (start_pos + i.min(n - 1)) as i32).collect();
        let write = self.cache.committed;
        Ok((self.plan_step(tokens, feats, &pos, &mask, write), n))
    }

    /// Apply half of [`DraftSession::chain`]: read the rows, then commit
    /// the `n` chain tokens into the draft cache accounting.
    pub fn finish_chain(&mut self, n: usize) -> Result<DraftOut> {
        let out = self.read_step()?;
        self.cache.push_chain(n)?;
        Ok(out)
    }

    /// Catch-up chain: commit `tokens` (the previously accepted path +
    /// bonus) into the draft cache with their features. Returns draft
    /// outputs per chain slot (the last row's logits seed the tree).
    pub fn chain(
        &mut self,
        tokens: &[u32],
        feats: &[f32],
        start_pos: usize,
    ) -> Result<DraftOut> {
        let (plan, n) = self.plan_chain(tokens, feats, start_pos)?;
        exec_single(self.be, &plan, &mut self.state)?;
        self.finish_chain(n)
    }

    /// Plan half of [`DraftSession::level`]; the scratch rows are
    /// reserved here (before the op runs), exactly like the fused path.
    /// Returns the plan plus the scratch offsets of the new rows.
    pub fn plan_level(
        &mut self,
        tokens: &[u32],
        feats: &[f32],
        pos: &[i32],
        anc_scratch: &[Vec<usize>],
    ) -> Result<(KernelPlan, Vec<usize>)> {
        let w = self.consts.draft_w;
        let n = tokens.len();
        if n == 0 || n > w {
            bail!("level width {n} outside 1..={w}");
        }
        let region = self.consts.draft_region;
        let off = self.cache.push_scratch(n)?;
        let mut mask = vec![0f32; w * region];
        for i in 0..n {
            for &a in &anc_scratch[i] {
                if a >= region {
                    bail!("scratch ancestor {a} outside region");
                }
                mask[i * region + a] = 1.0;
            }
            mask[i * region + off + i] = 1.0; // self
        }
        for i in n..w {
            mask[i * region + (off + i).min(region - 1)] = 1.0;
        }
        let write = self.cache.committed + off;
        Ok((self.plan_step(tokens, feats, pos, &mask, write), (off..off + n).collect()))
    }

    /// Apply half of [`DraftSession::level`]: read the expanded rows.
    pub fn finish_level(&mut self) -> Result<DraftOut> {
        self.read_step()
    }

    /// Expand one tree level: `tokens[i]` under scratch ancestors
    /// `anc_scratch[i]` (indices into the scratch region, self excluded).
    /// Returns (outputs, scratch offsets of the new rows).
    pub fn level(
        &mut self,
        tokens: &[u32],
        feats: &[f32],
        pos: &[i32],
        anc_scratch: &[Vec<usize>],
    ) -> Result<(DraftOut, Vec<usize>)> {
        let (plan, offsets) = self.plan_level(tokens, feats, pos, anc_scratch)?;
        exec_single(self.be, &plan, &mut self.state)?;
        Ok((self.finish_level()?, offsets))
    }
}

/// TriForce independent tiny draft LM with a streaming (sink+ring) cache.
pub struct TinySession<'a> {
    be: &'a dyn Backend,
    pub state: StateBuf,
    pub bucket: usize,
    /// valid rows (grows to bucket, then stays)
    pub valid: usize,
    /// ring write cursor
    pub write: usize,
    pub vocab: usize,
    consts: Consts,
}

impl<'a> TinySession<'a> {
    pub fn new(be: &'a dyn Backend) -> Result<TinySession<'a>> {
        let consts = be.consts().clone();
        let bucket = consts.tiny_bucket;
        let state = be.alloc_state(StateKind::Tiny, "tiny", bucket)?;
        let vocab = be.model("tiny")?.vocab;
        Ok(TinySession { be, state, bucket, valid: 0, write: 0, vocab, consts })
    }

    /// Resident device bytes of this session's state (pool accounting).
    pub fn state_bytes(&self) -> usize {
        self.be.state_bytes(StateKind::Tiny, "tiny", self.bucket).unwrap_or(0)
    }

    /// Host snapshot of the tiny state (checkpoint / swap-out).
    pub fn export(&self) -> Result<StateSnapshot> {
        self.be.export_state(StateKind::Tiny, "tiny", self.bucket, &self.state)
    }

    /// Replace the threaded state with an imported snapshot (the ring
    /// cursors live on the session object and survive the swap).
    pub fn restore(&mut self, snap: &StateSnapshot) -> Result<()> {
        if snap.kind != StateKind::Tiny || snap.bucket != self.bucket {
            bail!("snapshot {snap:?} does not match tiny session b{}", self.bucket);
        }
        self.state = self.be.import_state(snap)?;
        Ok(())
    }

    /// Park the tiny state into a page pool (suspend).
    pub fn park(&self, pool: &KvPool) -> Result<PagedState> {
        pool.park_state(self.be, StateKind::Tiny, "tiny", self.bucket, &self.state)
    }

    /// Rebuild the tiny state from a parked block table (ring cursors
    /// live on the session object and survive the swap).
    pub fn restore_paged(&mut self, pool: &KvPool, ps: &PagedState) -> Result<()> {
        if ps.kind != StateKind::Tiny || ps.bucket != self.bucket {
            bail!(
                "paged state {:?}/b{} does not match tiny session b{}",
                ps.kind, ps.bucket, self.bucket
            );
        }
        self.state = pool.unpark_state(self.be, ps)?;
        Ok(())
    }

    /// Drop the device state (swap-out); `restore` re-installs it.
    pub fn drop_state(&mut self) {
        self.state = StateBuf::nil();
    }

    /// Prefill the streaming cache with (up to) the last `bucket - γ`
    /// prompt tokens (TriForce keeps a sink+window draft cache; for the
    /// byte-level tiny LM a pure window suffices and is documented in
    /// DESIGN.md).
    pub fn prefill(&mut self, prompt: &[u32], gamma: usize) -> Result<Vec<f32>> {
        let c = self.consts.chunk;
        let keep = (self.bucket - gamma - 1).min(prompt.len());
        let tail = &prompt[prompt.len() - keep..];
        let base_pos = prompt.len() - keep;
        let mut logits = Vec::new();
        for (ci, chunk) in tail.chunks(c).enumerate() {
            let r = chunk.len();
            let mut toks = vec![PAD as i32; c];
            for (i, &t) in chunk.iter().enumerate() {
                toks[i] = t as i32;
            }
            let pos: Vec<i32> =
                (0..c).map(|i| (base_pos + ci * c + i) as i32).collect();
            let mask = chain_mask(r, c);
            let op = TinyForwardOp {
                t: c,
                tokens: &toks,
                pos: &pos,
                mask: &mask,
                kv_len: self.valid,
                write_pos: self.valid,
                last_idx: r - 1,
            };
            let state = take(&mut self.state);
            self.state = self.be.tiny_forward(&op, state)?;
            self.valid += r;
            self.write = self.valid;
            logits = self.read()?;
        }
        Ok(logits)
    }

    /// The backend this session executes on (see
    /// [`TargetSession::backend`]).
    pub fn backend(&self) -> &'a dyn Backend {
        self.be
    }

    /// Plan half of [`TinySession::step`]: one T=1 tiny forward at the
    /// current ring cursors (which only advance in
    /// [`TinySession::finish_step`], after the op ran).
    pub fn plan_step(&mut self, token: u32, pos: usize) -> KernelPlan {
        let mut plan = KernelPlan::new(OpClass::TinyForward, "tiny", self.bucket, 1);
        plan.tokens = vec![token as i32];
        plan.pos = vec![pos as i32];
        plan.mask = vec![1.0];
        plan.kv_len = self.valid.min(self.bucket);
        plan.write_pos = self.write;
        plan.last_idx = 0;
        plan
    }

    /// Apply half of [`TinySession::step`]: advance the ring cursors and
    /// read the kept logits row.
    pub fn finish_step(&mut self) -> Result<Vec<f32>> {
        if self.valid < self.bucket {
            self.valid += 1;
        }
        self.write = (self.write + 1) % self.bucket;
        self.read()
    }

    /// One draft step: process `token` at absolute `pos`, return logits.
    /// The cache is a streaming ring: once full, new rows overwrite the
    /// oldest slots (TriForce's StreamingLLM-style draft cache).
    pub fn step(&mut self, token: u32, pos: usize) -> Result<Vec<f32>> {
        let plan = self.plan_step(token, pos);
        exec_single(self.be, &plan, &mut self.state)?;
        self.finish_step()
    }

    /// Roll the write cursor back over `n` rejected draft rows (their
    /// slots are reused next round; see DESIGN.md on ring pollution).
    pub fn rollback(&mut self, n: usize) {
        let n = n.min(self.bucket);
        self.write = (self.write + self.bucket - n) % self.bucket;
        if self.valid < self.bucket {
            self.valid = self.valid.saturating_sub(n);
        }
    }

    fn read(&self) -> Result<Vec<f32>> {
        self.be.read_logits(&ReadOp::Tiny, &self.state)
    }
}
