//! Device-state sessions: thin stateful wrappers that pair a threaded
//! flat-state buffer with its rust-side cache accounting and the
//! manifest-driven executable calls.
//!
//! * [`TargetSession`] — the target model over a full bucket (prefill,
//!   verify/refresh, commit, score, gather, reads)
//! * [`PartialSession`] — the SpecPV partial cache (pverify + reads)
//! * [`DraftSession`] — the EAGLE-3 draft layer (prefill, chain, levels)
//! * [`TinySession`] — the independent TriForce draft LM (streaming ring)

use anyhow::{bail, Context, Result};
use xla::PjRtBuffer;

use crate::cache::{DraftCache, FullCache, PartialCache};
use crate::config::SpecPvConfig;
use crate::manifest::{Consts, ModelInfo, StateLayout};
use crate::model::{self, DraftOut, ReadOut};
use crate::offload::OffloadSim;
use crate::retrieval::GatherPlan;
use crate::runtime::{Arg, Runtime};
use crate::tokenizer::PAD;
use crate::tree::{chain_mask, FlatTree};

pub struct TargetSession<'a> {
    rt: &'a Runtime,
    pub size: String,
    pub bucket: usize,
    pub state: PjRtBuffer,
    pub cache: FullCache,
    pub info: ModelInfo,
    pub consts: Consts,
    pub layout: StateLayout,
    pub offload: OffloadSim,
}

impl<'a> TargetSession<'a> {
    /// Create a session whose bucket can hold `need` tokens.
    pub fn new(
        rt: &'a Runtime,
        size: &str,
        need: usize,
        offload: OffloadSim,
    ) -> Result<TargetSession<'a>> {
        let bucket = model::pick_full_bucket(&rt.manifest, size, need)?;
        let consts = rt.manifest.consts.clone();
        let info = rt.manifest.model(size)?.clone();
        let spec = rt
            .manifest
            .exec(&model::verify_name(size, bucket, consts.tree_t))?;
        let layout = spec.layout.context("verify exec missing layout")?;
        let state = rt.zero_state(layout.total)?;
        Ok(TargetSession {
            rt,
            size: size.to_string(),
            bucket,
            state,
            cache: FullCache::new(bucket),
            info,
            consts,
            layout,
            offload,
        })
    }

    fn kv_bpt(&self) -> usize {
        model::kv_bytes_per_token(&self.info)
    }

    /// Chunked prefill; pairs each chunk with the draft session (when
    /// present) so the draft consumes the chunk's features device-side.
    /// Returns (last-token logits, last-token fused features).
    pub fn prefill(
        &mut self,
        tokens: &[u32],
        mut draft: Option<&mut DraftSession<'a>>,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if tokens.is_empty() {
            bail!("empty prompt");
        }
        let c = self.consts.chunk;
        let name = model::verify_name(&self.size, self.bucket, c);
        let zero_prev = vec![0i32; self.consts.prev_max()];
        let mut last_real = 0usize;
        for (ci, chunk) in tokens.chunks(c).enumerate() {
            let r = chunk.len();
            last_real = r;
            let base = ci * c;
            let mut toks = vec![PAD as i32; c];
            for (i, &t) in chunk.iter().enumerate() {
                toks[i] = t as i32;
            }
            let pos: Vec<i32> = (0..c).map(|i| (base + i) as i32).collect();
            let mask = chain_mask(r, c);
            let out = self.rt.invoke(
                &name,
                &[
                    Arg::I32(&toks),
                    Arg::I32(&pos),
                    Arg::F32(&mask),
                    Arg::Buf(&self.state),
                    Arg::Scalar(self.cache.committed as i32),
                    Arg::I32(&zero_prev),
                    Arg::Scalar(0),
                ],
            )?;
            self.state = out;
            self.offload.touch_full(self.cache.committed + r, self.kv_bpt());
            if let Some(d) = draft.as_deref_mut() {
                d.prefill_chunk(&toks, r, &pos, &self.state)?;
            }
            self.cache.push_prefill(r)?;
        }
        let (logits, feats) = self.read_last(last_real - 1)?;
        Ok((logits, feats))
    }

    /// Verify a draft tree against the full cache (EAGLE3-full path and
    /// the SpecPV "Full" mode). Applies the pending fused compaction.
    pub fn verify_tree(&mut self, flat: &FlatTree, root_pos: usize) -> Result<ReadOut> {
        let t = self.consts.tree_t;
        let name = model::verify_name(&self.size, self.bucket, t);
        let (kv_len, idx, n_prev) = self.cache.take_pending(self.consts.prev_max())?;
        let pos = flat.positions(root_pos);
        let out = self.rt.invoke(
            &name,
            &[
                Arg::I32(&flat.tokens),
                Arg::I32(&pos),
                Arg::F32(&flat.mask),
                Arg::Buf(&self.state),
                Arg::Scalar(kv_len as i32),
                Arg::I32(&idx),
                Arg::Scalar(n_prev as i32),
            ],
        )?;
        self.state = out;
        self.offload
            .touch_full(self.cache.committed + flat.n, self.kv_bpt());
        self.read_window(0)
    }

    /// AR decode step (T=1): returns the token's logits row.
    pub fn decode_one(&mut self, token: u32, pos: usize) -> Result<Vec<f32>> {
        let name = model::verify_name(&self.size, self.bucket, 1);
        let (kv_len, idx, n_prev) = self.cache.take_pending(self.consts.prev_max())?;
        let mask = vec![1f32];
        let out = self.rt.invoke(
            &name,
            &[
                Arg::I32(&[token as i32]),
                Arg::I32(&[pos as i32]),
                Arg::F32(&mask),
                Arg::Buf(&self.state),
                Arg::Scalar(kv_len as i32),
                Arg::I32(&idx),
                Arg::Scalar(n_prev as i32),
            ],
        )?;
        self.state = out;
        self.offload.touch_full(self.cache.committed + 1, self.kv_bpt());
        self.cache.set_pending(vec![0], self.consts.prev_window())?;
        let (logits, _) = self.read_last(0)?;
        Ok(logits)
    }

    /// Refresh verification (SpecPV): a pv chain of `chain` tokens
    /// followed by the draft tree, against the full cache, using the
    /// `t_refresh`-wide executable. Returns the read window positioned at
    /// the tree (rows 0.. = chain.len() offset applied).
    pub fn verify_refresh(
        &mut self,
        chain: &[u32],
        chain_start_pos: usize,
        flat: &FlatTree,
        t_refresh: usize,
    ) -> Result<ReadOut> {
        let n_chain = chain.len();
        let t_tree = flat.tokens.len();
        if n_chain + t_tree > t_refresh {
            bail!("refresh overflow: {n_chain}+{t_tree} > {t_refresh}");
        }
        let name = model::verify_name(&self.size, self.bucket, t_refresh);
        let (kv_len, idx, n_prev) = self.cache.take_pending(self.consts.prev_max())?;

        let mut toks = vec![PAD as i32; t_refresh];
        let mut pos = vec![0i32; t_refresh];
        for (i, &t) in chain.iter().enumerate() {
            toks[i] = t as i32;
            pos[i] = (chain_start_pos + i) as i32;
        }
        let root_pos = chain_start_pos + n_chain;
        let tree_pos = flat.positions(root_pos);
        for i in 0..t_tree {
            toks[n_chain + i] = flat.tokens[i];
            pos[n_chain + i] = tree_pos[i];
        }
        let mask = crate::tree::refresh_mask(n_chain, flat, t_refresh);
        let out = self.rt.invoke(
            &name,
            &[
                Arg::I32(&toks),
                Arg::I32(&pos),
                Arg::F32(&mask),
                Arg::Buf(&self.state),
                Arg::Scalar(kv_len as i32),
                Arg::I32(&idx),
                Arg::Scalar(n_prev as i32),
            ],
        )?;
        self.state = out;
        self.offload
            .touch_full(self.cache.committed + n_chain + flat.n, self.kv_bpt());
        // window positioned so the tree starts at row 0 when possible
        self.read_window(n_chain)
    }

    /// Standalone commit after a Refresh: keep `rows` (chain + accepted
    /// tree path, window-relative, strictly increasing) of the last step.
    pub fn commit_now(&mut self, rows: &[usize], window: usize) -> Result<()> {
        let name = model::commit_name(&self.size, self.bucket, window);
        let mut idx = vec![0i32; window];
        for (j, &r) in rows.iter().enumerate() {
            if r >= window {
                bail!("commit row {r} outside window {window}");
            }
            idx[j] = r as i32;
        }
        let out = self.rt.invoke(
            &name,
            &[
                Arg::Buf(&self.state),
                Arg::I32(&idx),
                Arg::Scalar(rows.len() as i32),
                Arg::Scalar(self.cache.committed as i32),
            ],
        )?;
        self.state = out;
        self.offload.touch_full(self.cache.committed, self.kv_bpt());
        self.cache.commit_now(rows.len())
    }

    /// Retrieval scores over the committed cache using the queries the
    /// last (refresh) verification wrote. Flat `[L, 3, NB]`.
    pub fn score(&mut self, n_queries: usize) -> Result<Vec<f32>> {
        let name = model::score_name(&self.size, self.bucket);
        let out = self.rt.invoke_download(
            &name,
            &[
                Arg::Buf(&self.state),
                Arg::Scalar(self.cache.committed as i32),
                Arg::Scalar(n_queries as i32),
            ],
        )?;
        self.offload.touch_full(self.cache.committed, self.kv_bpt());
        Ok(out)
    }

    /// Assemble a fresh partial state from a gather plan.
    pub fn gather(&mut self, plan: &GatherPlan, p_bucket: usize) -> Result<PjRtBuffer> {
        let name = model::gather_name(&self.size, self.bucket, p_bucket);
        let nsel = plan.block_idx[0].len();
        let mut idx = Vec::with_capacity(self.info.n_layer * nsel);
        for l in &plan.block_idx {
            idx.extend_from_slice(l);
        }
        let out = self
            .rt
            .invoke(&name, &[Arg::Buf(&self.state), Arg::I32(&idx)])?;
        self.offload.touch_full(self.cache.committed, self.kv_bpt());
        Ok(out)
    }

    /// Logits+feats window of `qrows` rows starting at `start`.
    pub fn read_window(&self, start: usize) -> Result<ReadOut> {
        let name = model::read_full_name(&self.size, self.bucket);
        let data = self.rt.invoke_download(
            &name,
            &[Arg::Buf(&self.state), Arg::Scalar(start as i32)],
        )?;
        ReadOut::new(
            data,
            self.consts.qrows,
            self.info.vocab,
            3 * self.info.d_model,
        )
    }

    /// Single row logits+feats at `idx` (prefill tail).
    pub fn read_last(&self, idx: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let name = model::read_last_name(&self.size, self.bucket);
        let data = self.rt.invoke_download(
            &name,
            &[Arg::Buf(&self.state), Arg::Scalar(idx as i32)],
        )?;
        let v = self.info.vocab;
        Ok((data[..v].to_vec(), data[v..].to_vec()))
    }
}

/// SpecPV partial-cache session.
pub struct PartialSession<'a> {
    rt: &'a Runtime,
    pub size: String,
    pub bucket: usize,
    pub state: Option<PjRtBuffer>,
    pub cache: PartialCache,
    pub info: ModelInfo,
    pub consts: Consts,
}

impl<'a> PartialSession<'a> {
    pub fn new(
        rt: &'a Runtime,
        size: &str,
        cfg: &SpecPvConfig,
    ) -> Result<PartialSession<'a>> {
        let consts = rt.manifest.consts.clone();
        let need = cfg.core_tokens(consts.block) + consts.tree_t + cfg.buffer_cap;
        let bucket = model::pick_partial_bucket(&rt.manifest, size, need)?;
        Ok(PartialSession {
            rt,
            size: size.to_string(),
            bucket,
            state: None,
            cache: PartialCache::new(bucket, cfg.buffer_cap),
            info: rt.manifest.model(size)?.clone(),
            consts,
        })
    }

    /// Install a freshly gathered core.
    pub fn install(&mut self, state: PjRtBuffer, core_len: usize) {
        self.state = Some(state);
        self.cache.refresh(core_len);
    }

    pub fn ready(&self) -> bool {
        self.state.is_some()
    }

    /// Partial verification of a draft tree (paper §3.2). Same ABI as the
    /// full verify, small bucket.
    pub fn verify_tree(&mut self, flat: &FlatTree, root_pos: usize) -> Result<ReadOut> {
        let state = match self.state.take() {
            Some(s) => s,
            None => bail!("partial cache not initialised"),
        };
        let t = self.consts.tree_t;
        let name = model::pverify_name(&self.size, self.bucket, t);
        let (kv_len, idx, n_prev) = self.cache.take_pending(self.consts.prev_max())?;
        let pos = flat.positions(root_pos);
        let out = self.rt.invoke(
            &name,
            &[
                Arg::I32(&flat.tokens),
                Arg::I32(&pos),
                Arg::F32(&flat.mask),
                Arg::Buf(&state),
                Arg::Scalar(kv_len as i32),
                Arg::I32(&idx),
                Arg::Scalar(n_prev as i32),
            ],
        )?;
        self.state = Some(out);
        let name = model::read_partial_name(&self.size, self.bucket);
        let data = self.rt.invoke_download(
            &name,
            &[Arg::Buf(self.state.as_ref().unwrap())],
        )?;
        ReadOut::new(data, t, self.info.vocab, 3 * self.info.d_model)
    }
}

/// EAGLE-3 draft session (one decoder layer, own bucket).
pub struct DraftSession<'a> {
    rt: &'a Runtime,
    pub size: String,
    pub bucket: usize,
    pub state: PjRtBuffer,
    pub cache: DraftCache,
    pub info: ModelInfo,
    pub consts: Consts,
}

impl<'a> DraftSession<'a> {
    pub fn new(rt: &'a Runtime, size: &str, bucket: usize) -> Result<DraftSession<'a>> {
        let consts = rt.manifest.consts.clone();
        let spec = rt
            .manifest
            .exec(&model::draft_step_name(size, bucket))?;
        let layout = spec.layout.context("draft exec missing layout")?;
        let state = rt.zero_state(layout.total)?;
        Ok(DraftSession {
            rt,
            size: size.to_string(),
            bucket,
            state,
            cache: DraftCache::new(bucket, consts.draft_region),
            info: rt.manifest.model(size)?.clone(),
            consts,
        })
    }

    /// Consume one target prefill chunk's features (device-side).
    pub fn prefill_chunk(
        &mut self,
        toks: &[i32],
        real: usize,
        pos: &[i32],
        target_state: &PjRtBuffer,
    ) -> Result<()> {
        let c = self.consts.chunk;
        let name = model::draft_prefill_name(&self.size, self.bucket);
        let mask = chain_mask(real, c);
        let out = self.rt.invoke(
            &name,
            &[
                Arg::I32(toks),
                Arg::Buf(target_state),
                Arg::I32(pos),
                Arg::F32(&mask),
                Arg::Buf(&self.state),
                Arg::Scalar(self.cache.committed as i32),
                Arg::Scalar(self.cache.committed as i32),
            ],
        )?;
        self.state = out;
        self.cache.push_prefill(real)
    }

    /// Hidden state of prefill-chunk row `idx` (the recycled feature for
    /// the first draft after prefill).
    pub fn read_hidden_row(&self, idx: usize) -> Result<Vec<f32>> {
        let name = format!("read_draft_row_{}_b{}", self.size, self.bucket);
        self.rt.invoke_download(
            &name,
            &[Arg::Buf(&self.state), Arg::Scalar(idx as i32)],
        )
    }

    fn step(
        &mut self,
        tokens: &[u32],
        feats: &[f32],
        pos: &[i32],
        mask: &[f32],
        write_pos: usize,
    ) -> Result<DraftOut> {
        let w = self.consts.draft_w;
        let name = model::draft_step_name(&self.size, self.bucket);
        let mut toks = vec![PAD as i32; w];
        for (i, &t) in tokens.iter().enumerate() {
            toks[i] = t as i32;
        }
        let out = self.rt.invoke(
            &name,
            &[
                Arg::I32(&toks),
                Arg::F32(feats),
                Arg::I32(pos),
                Arg::F32(mask),
                Arg::Buf(&self.state),
                Arg::Scalar(self.cache.committed as i32),
                Arg::Scalar(write_pos as i32),
            ],
        )?;
        self.state = out;
        let name = model::read_draft_name(&self.size, self.bucket);
        let data = self
            .rt
            .invoke_download(&name, &[Arg::Buf(&self.state)])?;
        DraftOut::new(data, w, self.info.vocab, self.info.d_model)
    }

    /// Catch-up chain: commit `tokens` (the previously accepted path +
    /// bonus) into the draft cache with their features. Returns draft
    /// outputs per chain slot (the last row's logits seed the tree).
    pub fn chain(
        &mut self,
        tokens: &[u32],
        feats: &[f32],
        start_pos: usize,
    ) -> Result<DraftOut> {
        let w = self.consts.draft_w;
        let n = tokens.len();
        if n == 0 || n > w {
            bail!("chain length {n} outside 1..={w}");
        }
        let region = self.consts.draft_region;
        // chain mask within the region: token i sees region cols 0..=i
        let mut mask = vec![0f32; w * region];
        for i in 0..w {
            for j in 0..=i.min(region - 1) {
                mask[i * region + j] = 1.0;
            }
        }
        let pos: Vec<i32> = (0..w).map(|i| (start_pos + i.min(n - 1)) as i32).collect();
        let write = self.cache.committed;
        let out = self.step(tokens, feats, &pos, &mask, write)?;
        self.cache.push_chain(n)?;
        Ok(out)
    }

    /// Expand one tree level: `tokens[i]` under scratch ancestors
    /// `anc_scratch[i]` (indices into the scratch region, self excluded).
    /// Returns (outputs, scratch offsets of the new rows).
    pub fn level(
        &mut self,
        tokens: &[u32],
        feats: &[f32],
        pos: &[i32],
        anc_scratch: &[Vec<usize>],
    ) -> Result<(DraftOut, Vec<usize>)> {
        let w = self.consts.draft_w;
        let n = tokens.len();
        if n == 0 || n > w {
            bail!("level width {n} outside 1..={w}");
        }
        let region = self.consts.draft_region;
        let off = self.cache.push_scratch(n)?;
        let mut mask = vec![0f32; w * region];
        for i in 0..n {
            for &a in &anc_scratch[i] {
                if a >= region {
                    bail!("scratch ancestor {a} outside region");
                }
                mask[i * region + a] = 1.0;
            }
            mask[i * region + off + i] = 1.0; // self
        }
        for i in n..w {
            mask[i * region + (off + i).min(region - 1)] = 1.0;
        }
        let write = self.cache.committed + off;
        let out = self.step(tokens, feats, pos, &mask, write)?;
        Ok((out, (off..off + n).collect()))
    }
}

/// TriForce independent tiny draft LM with a streaming (sink+ring) cache.
pub struct TinySession<'a> {
    rt: &'a Runtime,
    pub state: PjRtBuffer,
    pub bucket: usize,
    /// valid rows (grows to bucket, then stays)
    pub valid: usize,
    /// ring write cursor
    pub write: usize,
    pub vocab: usize,
    consts: Consts,
}

impl<'a> TinySession<'a> {
    pub fn new(rt: &'a Runtime) -> Result<TinySession<'a>> {
        let consts = rt.manifest.consts.clone();
        let bucket = consts.tiny_bucket;
        let spec = rt.manifest.exec(&format!("verify_tiny_b{bucket}_t1"))?;
        let layout = spec.layout.context("tiny exec missing layout")?;
        let state = rt.zero_state(layout.total)?;
        let vocab = rt.manifest.model("tiny")?.vocab;
        Ok(TinySession { rt, state, bucket, valid: 0, write: 0, vocab, consts })
    }

    /// Prefill the streaming cache with (up to) the last `bucket - γ`
    /// prompt tokens (TriForce keeps a sink+window draft cache; for the
    /// byte-level tiny LM a pure window suffices and is documented in
    /// DESIGN.md).
    pub fn prefill(&mut self, prompt: &[u32], gamma: usize) -> Result<Vec<f32>> {
        let c = self.consts.chunk;
        let keep = (self.bucket - gamma - 1).min(prompt.len());
        let tail = &prompt[prompt.len() - keep..];
        let base_pos = prompt.len() - keep;
        let name = format!("verify_tiny_b{}_t{}", self.bucket, c);
        let mut logits = Vec::new();
        for (ci, chunk) in tail.chunks(c).enumerate() {
            let r = chunk.len();
            let mut toks = vec![PAD as i32; c];
            for (i, &t) in chunk.iter().enumerate() {
                toks[i] = t as i32;
            }
            let pos: Vec<i32> =
                (0..c).map(|i| (base_pos + ci * c + i) as i32).collect();
            let mask = chain_mask(r, c);
            let out = self.rt.invoke(
                &name,
                &[
                    Arg::I32(&toks),
                    Arg::I32(&pos),
                    Arg::F32(&mask),
                    Arg::Buf(&self.state),
                    Arg::Scalar(self.valid as i32),
                    Arg::Scalar(self.valid as i32),
                    Arg::Scalar((r - 1) as i32),
                ],
            )?;
            self.state = out;
            self.valid += r;
            self.write = self.valid;
            logits = self.read()?;
        }
        Ok(logits)
    }

    /// One draft step: process `token` at absolute `pos`, return logits.
    /// The cache is a streaming ring: once full, new rows overwrite the
    /// oldest slots (TriForce's StreamingLLM-style draft cache).
    pub fn step(&mut self, token: u32, pos: usize) -> Result<Vec<f32>> {
        let name = format!("verify_tiny_b{}_t1", self.bucket);
        let kv_len = self.valid.min(self.bucket);
        let out = self.rt.invoke(
            &name,
            &[
                Arg::I32(&[token as i32]),
                Arg::I32(&[pos as i32]),
                Arg::F32(&[1.0]),
                Arg::Buf(&self.state),
                Arg::Scalar(kv_len as i32),
                Arg::Scalar(self.write as i32),
                Arg::Scalar(0),
            ],
        )?;
        self.state = out;
        if self.valid < self.bucket {
            self.valid += 1;
        }
        self.write = (self.write + 1) % self.bucket;
        self.read()
    }

    /// Roll the write cursor back over `n` rejected draft rows (their
    /// slots are reused next round; see DESIGN.md on ring pollution).
    pub fn rollback(&mut self, n: usize) {
        let n = n.min(self.bucket);
        self.write = (self.write + self.bucket - n) % self.bucket;
        if self.valid < self.bucket {
            self.valid = self.valid.saturating_sub(n);
        }
    }

    fn read(&self) -> Result<Vec<f32>> {
        let name = format!("read_tiny_b{}", self.bucket);
        self.rt
            .invoke_download(&name, &[Arg::Buf(&self.state)])
    }
}
