//! TokenSwift-like baseline (Wu et al.): Medusa-style multi-position
//! heads draft a static tree; the target verifies against the full KV
//! cache. Token-reutilization and contextual-penalty (ultra-long-sequence
//! techniques with little effect at our scale, as the paper itself notes
//! in §4.2) are omitted; the Medusa-draft + full-verification structure
//! is what Table 1 row 2 measures.
//!
//! The Medusa head projection is a one-shot host-side matmul and stays
//! inline; the tree verification surfaces as a batchable kernel plan
//! (DESIGN.md §12), so concurrent sessions' verifies fuse.

use anyhow::{bail, Result};

use crate::backend::{Backend, StateBuf, StateKind};
use crate::config::Config;
use crate::kvstore::{KvCtx, KvPool, PagedState};
use crate::manifest::Consts;
use crate::metrics::GenStats;
use crate::model::bucket_need;
use crate::offload::OffloadSim;
use crate::sampling::{pick_token, top_k};
use crate::tree::Tree;
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::plan::{exec_single, Drive, KernelPlan};
use super::session::TargetSession;
use super::spec_full::{accept_round, tree_picks};
use super::{Engine, EngineSession, GenRequest, GenResult, SessionOut, StepOutcome};
use crate::policy::SpecObservation;

pub struct TokenSwiftEngine {
    cfg: Config,
}

impl TokenSwiftEngine {
    pub fn new(cfg: Config) -> TokenSwiftEngine {
        TokenSwiftEngine { cfg }
    }
}

/// Build the static Medusa tree from the 3 head distributions:
/// root → top-4 of head 1 → ×top-2 of head 2 → best path gets head 3's
/// top-1 (≤ 14 nodes).
fn medusa_tree(bonus: u32, heads: &[f32], vocab: usize) -> Tree {
    let h1 = &heads[0..vocab];
    let h2 = &heads[vocab..2 * vocab];
    let h3 = &heads[2 * vocab..3 * vocab];
    let l1 = crate::sampling::log_softmax(h1);
    let l2 = crate::sampling::log_softmax(h2);
    let l3 = crate::sampling::log_softmax(h3);

    let mut tree = Tree::new(bonus);
    let mut best_leaf = 0usize;
    let mut best_score = f32::NEG_INFINITY;
    for &a in top_k(&l1, 4).iter() {
        let ia = tree.add(0, a as u32, l1[a]);
        for &b in top_k(&l2, 2).iter() {
            let ib = tree.add(ia, b as u32, l2[b]);
            if tree.nodes[ib].score > best_score {
                best_score = tree.nodes[ib].score;
                best_leaf = ib;
            }
        }
    }
    let c = top_k(&l3, 1)[0];
    tree.add(best_leaf, c as u32, l3[c]);
    tree
}

/// Where a TokenSwift step is between `drive()` calls.
enum Phase {
    Idle,
    /// tree verification in flight
    Verify { tree: Tree, flat_n: usize },
}

pub struct TokenSwiftSession<'rt> {
    be: &'rt dyn Backend,
    target: TargetSession<'rt>,
    pool: KvPool,
    out: SessionOut,
    bonus: u32,
    /// top-layer feature of the deepest accepted node (drives the heads)
    feat: Vec<f32>,
    rng: Rng,
    stats: GenStats,
    consts: Consts,
    vocab: usize,
    d_model: usize,
    prompt_len: usize,
    temperature: f32,
    phase: Phase,
    pending: Option<KernelPlan>,
    sw: Stopwatch,
    /// draft tokens offered to verification (policy layer, DESIGN.md §16)
    proposed: u64,
}

impl Engine for TokenSwiftEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::TokenSwift
    }

    fn start<'be>(
        &self,
        be: &'be dyn Backend,
        req: &GenRequest,
        kv: &KvCtx,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let consts = be.consts().clone();
        let need = bucket_need(req.prompt.len(), req.max_new, &consts);
        let mut target = TargetSession::new(
            be,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;
        // Medusa heads read the top-layer feature only; no draft KV needed.
        let vocab = target.info.vocab;
        let h = target.info.d_model;

        let mut sw = Stopwatch::new();
        let (logits, feat_last) = target.prefill(&req.prompt, None, kv)?;
        stats.prefill_secs = sw.lap();

        let bonus = pick_token(&logits, req.temperature, &mut rng);
        let mut out = SessionOut::new(req.max_new);
        out.push_first(bonus);
        let feat = feat_last[2 * h..3 * h].to_vec();

        Ok(Box::new(TokenSwiftSession {
            be,
            target,
            pool: kv.pool.clone(),
            out,
            bonus,
            feat,
            rng,
            stats,
            consts,
            vocab,
            d_model: h,
            prompt_len: req.prompt.len(),
            temperature: req.temperature,
            phase: Phase::Idle,
            pending: None,
            sw: Stopwatch::new(),
            proposed: 0,
        }))
    }
}

impl EngineSession for TokenSwiftSession<'_> {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::TokenSwift
    }

    fn is_finished(&self) -> bool {
        self.out.done
    }

    fn emitted(&self) -> usize {
        self.out.len()
    }

    fn step(&mut self) -> Result<StepOutcome> {
        loop {
            match self.drive()? {
                Drive::Complete(o) => return Ok(o),
                Drive::Pending => {
                    let plan =
                        self.pending.as_ref().expect("pending plan after Drive::Pending");
                    exec_single(self.be, plan, &mut self.target.state)?;
                }
                Drive::Unsupported => {
                    unreachable!("tokenswift sessions implement the protocol")
                }
            }
        }
    }

    fn drive(&mut self) -> Result<Drive> {
        loop {
            let phase = std::mem::replace(&mut self.phase, Phase::Idle);
            match phase {
                Phase::Idle => {
                    if self.out.done {
                        return Ok(Drive::Complete(self.out.outcome()));
                    }
                    self.sw = Stopwatch::new();

                    // --- Medusa draft (inline host-side projection) -----
                    let heads = self.be.medusa(&self.target.size, &self.feat)?;
                    let tree = medusa_tree(self.bonus, &heads, self.vocab);
                    self.stats.draft_secs += self.sw.lap();

                    let flat = tree.flatten(self.consts.tree_t);
                    let root_pos = self.prompt_len + self.out.len() - 1;
                    let plan = self.target.plan_verify_tree(&flat, root_pos)?;
                    self.pending = Some(plan);
                    self.phase = Phase::Verify { tree, flat_n: flat.n };
                    return Ok(Drive::Pending);
                }
                Phase::Verify { tree, flat_n } => {
                    self.pending = None;
                    let h = self.d_model;
                    let read = self.target.finish_verify_tree(flat_n)?;
                    self.stats.verify_secs += self.sw.lap();

                    let picks =
                        tree_picks(&tree, &read, 0, self.temperature, &mut self.rng);
                    let acc = accept_round(&tree, &picks);
                    self.stats.verify_steps += 1;
                    self.proposed += flat_n.saturating_sub(1) as u64;
                    self.stats.full_steps += 1;

                    let kept = self.out.push_round(&acc.path_tokens, acc.bonus);
                    self.stats.accepted_total += kept;

                    let mut rows = vec![0usize];
                    rows.extend(&acc.path_idx);
                    self.target.cache.set_pending(rows, self.consts.prev_window())?;

                    self.feat = read.feats(acc.deepest)[2 * h..3 * h].to_vec();
                    self.bonus = acc.bonus;
                    self.stats.other_secs += self.sw.lap();

                    return Ok(Drive::Complete(self.out.outcome()));
                }
            }
        }
    }

    fn take_pending(&mut self) -> Option<(KernelPlan, StateBuf)> {
        let plan = self.pending.take()?;
        let state = std::mem::replace(&mut self.target.state, StateBuf::nil());
        Some((plan, state))
    }

    fn restore_pending(&mut self, state: StateBuf) {
        self.target.state = state;
    }

    /// Observe-only: the Medusa tree shape is fixed by the head count, so
    /// the session reports acceptance but ignores depth directives
    /// (`apply_policy` keeps its default no-op).
    fn spec_observe(&self) -> Option<SpecObservation> {
        Some(SpecObservation {
            proposed: self.proposed,
            committed: self.stats.accepted_total as u64,
            verify_steps: self.stats.verify_steps as u64,
            full_steps: self.stats.full_steps as u64,
            partial_steps: 0,
            refresh_steps: 0,
            context_len: self.prompt_len + self.out.len(),
            depth: 3,
            pv_len: 0,
        })
    }

    fn finish(self: Box<Self>) -> GenResult {
        let TokenSwiftSession { target, out, mut stats, .. } = *self;
        stats.decode_secs = stats.draft_secs + stats.verify_secs + stats.other_secs;
        stats.new_tokens = out.tokens.len();
        stats.offload_secs = target.offload.secs;
        GenResult { tokens: out.tokens, stats }
    }

    fn state_bytes(&self) -> usize {
        self.target.state_bytes()
    }

    fn suspend(&mut self) -> Result<Vec<PagedState>> {
        let ps = self.target.park(&self.pool)?;
        self.target.drop_state();
        Ok(vec![ps])
    }

    fn resume(&mut self, states: Vec<PagedState>) -> Result<()> {
        let mut full = false;
        for ps in &states {
            match ps.kind {
                StateKind::Full => {
                    self.target.restore_paged(&self.pool, ps)?;
                    full = true;
                }
                k => bail!("unexpected {k:?} block table for a tokenswift session"),
            }
        }
        if !full {
            bail!("tokenswift resume needs a full block table");
        }
        for ps in &states {
            self.pool.free_state(ps);
        }
        Ok(())
    }
}
