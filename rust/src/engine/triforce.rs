//! TriForce-like baseline (Sun et al.): an **independent** tiny draft LM
//! with a streaming (ring) cache proposes a γ-token chain; the target
//! verifies against the full KV cache every step (lossless — TriForce
//! never refreshes a partial target cache).
//!
//! Substitutions vs the original (DESIGN.md §3): the Qwama-0.5B draft is
//! replaced by our 2-layer tiny char-LM trained on the same corpus, and
//! the hierarchical (two-stage) speculation is collapsed into one stage —
//! the properties under test (independent draft, full verification,
//! streaming draft cache) are preserved.

use anyhow::{bail, Result};

use crate::backend::{Backend, StateKind, StateSnapshot};
use crate::config::Config;
use crate::kvstore::KvStore;
use crate::manifest::Consts;
use crate::metrics::GenStats;
use crate::model::bucket_need;
use crate::offload::OffloadSim;
use crate::sampling::pick_token;
use crate::tree::{chain_mask, FlatTree};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::session::{TargetSession, TinySession};
use super::{Engine, EngineSession, GenRequest, GenResult, SessionOut, StepOutcome};

pub struct TriForceEngine {
    cfg: Config,
}

impl TriForceEngine {
    pub fn new(cfg: Config) -> TriForceEngine {
        TriForceEngine { cfg }
    }
}

/// Flatten a token chain as a degenerate "tree" (row i = depth i).
fn chain_flat(tokens: &[u32], t_pad: usize) -> FlatTree {
    let mut toks = vec![crate::tokenizer::PAD as i32; t_pad];
    let mut depth = vec![0usize; t_pad];
    for (i, &t) in tokens.iter().enumerate() {
        toks[i] = t as i32;
        depth[i] = i;
    }
    FlatTree {
        tokens: toks,
        depth,
        mask: chain_mask(tokens.len(), t_pad),
        n: tokens.len(),
    }
}

pub struct TriForceSession<'rt> {
    target: TargetSession<'rt>,
    tiny: TinySession<'rt>,
    out: SessionOut,
    bonus: u32,
    rng: Rng,
    stats: GenStats,
    consts: Consts,
    gamma: usize,
    prompt_len: usize,
    temperature: f32,
}

impl Engine for TriForceEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::TriForce
    }

    fn start<'be>(
        &self,
        be: &'be dyn Backend,
        req: &GenRequest,
        prefix: Option<&KvStore>,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let consts = be.consts().clone();
        let gamma = self.cfg.chain_gamma;
        let need = bucket_need(req.prompt.len(), req.max_new, &consts);
        let mut target = TargetSession::new(
            be,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;
        let mut tiny = TinySession::new(be)?;

        let mut sw = Stopwatch::new();
        let (logits, _) = target.prefill(&req.prompt, None, prefix)?;
        tiny.prefill(&req.prompt, gamma)?;
        stats.prefill_secs = sw.lap();

        let bonus = pick_token(&logits, req.temperature, &mut rng);
        let mut out = SessionOut::new(req.max_new);
        out.push_first(bonus);

        Ok(Box::new(TriForceSession {
            target,
            tiny,
            out,
            bonus,
            rng,
            stats,
            consts,
            gamma,
            prompt_len: req.prompt.len(),
            temperature: req.temperature,
        }))
    }
}

impl EngineSession for TriForceSession<'_> {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::TriForce
    }

    fn is_finished(&self) -> bool {
        self.out.done
    }

    fn emitted(&self) -> usize {
        self.out.len()
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if self.out.done {
            return Ok(self.out.outcome());
        }
        let mut sw = Stopwatch::new();
        let gamma = self.gamma;

        // --- draft a γ-chain with the tiny LM --------------------------
        let mut chain: Vec<u32> = vec![self.bonus];
        let mut cur = self.bonus;
        for g in 0..gamma {
            let pos = self.prompt_len + self.out.len() - 1 + g;
            let lg = self.tiny.step(cur, pos)?;
            cur = pick_token(&lg, self.temperature, &mut self.rng);
            chain.push(cur);
        }
        self.stats.draft_secs += sw.lap();

        // --- target verifies [bonus, d1..dγ] ---------------------------
        let flat = chain_flat(&chain, self.consts.tree_t);
        let root_pos = self.prompt_len + self.out.len() - 1;
        let read = self.target.verify_tree(&flat, root_pos)?;
        self.stats.verify_secs += sw.lap();

        // greedy walk down the chain
        let mut accepted = 0usize;
        let mut next = pick_token(read.logits(0), self.temperature, &mut self.rng);
        while accepted < gamma && chain[accepted + 1] == next {
            accepted += 1;
            next = pick_token(read.logits(accepted), self.temperature, &mut self.rng);
        }
        self.stats.verify_steps += 1;
        self.stats.full_steps += 1;

        let kept = self.out.push_round(&chain[1..=accepted], next);
        self.stats.accepted_total += kept;

        // rejected tiny-cache rows are reused next round
        self.tiny.rollback(gamma - accepted);

        let rows: Vec<usize> = (0..=accepted).collect();
        self.target.cache.set_pending(rows, self.consts.prev_window())?;
        self.bonus = next;
        self.stats.other_secs += sw.lap();

        Ok(self.out.outcome())
    }

    fn finish(self: Box<Self>) -> GenResult {
        let TriForceSession { target, out, mut stats, .. } = *self;
        stats.decode_secs = stats.draft_secs + stats.verify_secs + stats.other_secs;
        stats.new_tokens = out.tokens.len();
        stats.offload_secs = target.offload.secs;
        GenResult { tokens: out.tokens, stats }
    }

    fn state_bytes(&self) -> usize {
        self.target.state_bytes() + self.tiny.state_bytes()
    }

    fn suspend(&mut self) -> Result<Vec<StateSnapshot>> {
        let snaps = vec![self.target.export()?, self.tiny.export()?];
        self.target.drop_state();
        self.tiny.drop_state();
        Ok(snaps)
    }

    fn resume(&mut self, snaps: Vec<StateSnapshot>) -> Result<()> {
        let (mut full, mut tiny) = (false, false);
        for s in &snaps {
            match s.kind {
                StateKind::Full => {
                    self.target.restore(s)?;
                    full = true;
                }
                StateKind::Tiny => {
                    self.tiny.restore(s)?;
                    tiny = true;
                }
                k => bail!("unexpected {k:?} snapshot for a triforce session"),
            }
        }
        if !(full && tiny) {
            bail!("triforce resume needs full + tiny snapshots");
        }
        Ok(())
    }
}
