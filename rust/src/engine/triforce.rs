//! TriForce-like baseline (Sun et al.): an **independent** tiny draft LM
//! with a streaming (ring) cache proposes a γ-token chain; the target
//! verifies against the full KV cache every step (lossless — TriForce
//! never refreshes a partial target cache).
//!
//! Substitutions vs the original (DESIGN.md §3): the Qwama-0.5B draft is
//! replaced by our 2-layer tiny char-LM trained on the same corpus, and
//! the hierarchical (two-stage) speculation is collapsed into one stage —
//! the properties under test (independent draft, full verification,
//! streaming draft cache) are preserved.
//!
//! Each step is a plan/apply machine (DESIGN.md §12): the γ tiny-LM
//! draft steps and the chain verification surface as batchable kernel
//! plans, so concurrent TriForce sessions fuse their tiny forwards and
//! verifies.

use anyhow::{bail, Result};

use crate::backend::{Backend, StateBuf, StateKind};
use crate::config::Config;
use crate::kvstore::{KvCtx, KvPool, PagedState};
use crate::manifest::Consts;
use crate::metrics::GenStats;
use crate::model::bucket_need;
use crate::offload::OffloadSim;
use crate::sampling::pick_token;
use crate::tree::{chain_mask, FlatTree};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::plan::{exec_single, Drive, KernelPlan, OpClass};
use super::session::{TargetSession, TinySession};
use super::{Engine, EngineSession, GenRequest, GenResult, SessionOut, StepOutcome};
use crate::policy::{PolicyDirective, SpecObservation};

pub struct TriForceEngine {
    cfg: Config,
}

impl TriForceEngine {
    pub fn new(cfg: Config) -> TriForceEngine {
        TriForceEngine { cfg }
    }
}

/// Flatten a token chain as a degenerate "tree" (row i = depth i).
fn chain_flat(tokens: &[u32], t_pad: usize) -> FlatTree {
    let mut toks = vec![crate::tokenizer::PAD as i32; t_pad];
    let mut depth = vec![0usize; t_pad];
    for (i, &t) in tokens.iter().enumerate() {
        toks[i] = t as i32;
        depth[i] = i;
    }
    FlatTree {
        tokens: toks,
        depth,
        mask: chain_mask(tokens.len(), t_pad),
        n: tokens.len(),
    }
}

/// Where a TriForce step is between `drive()` calls.
enum Phase {
    Idle,
    /// tiny-LM chain drafting: `g` draft steps consumed so far
    Tiny { g: usize, chain: Vec<u32> },
    /// chain verification in flight
    Verify { chain: Vec<u32> },
}

pub struct TriForceSession<'rt> {
    be: &'rt dyn Backend,
    target: TargetSession<'rt>,
    tiny: TinySession<'rt>,
    pool: KvPool,
    out: SessionOut,
    bonus: u32,
    rng: Rng,
    stats: GenStats,
    consts: Consts,
    gamma: usize,
    prompt_len: usize,
    temperature: f32,
    phase: Phase,
    pending: Option<KernelPlan>,
    sw: Stopwatch,
    /// draft tokens offered to verification (policy layer, DESIGN.md §16)
    proposed: u64,
}

impl Engine for TriForceEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::TriForce
    }

    fn start<'be>(
        &self,
        be: &'be dyn Backend,
        req: &GenRequest,
        kv: &KvCtx,
    ) -> Result<Box<dyn EngineSession + 'be>> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let consts = be.consts().clone();
        let gamma = self.cfg.chain_gamma;
        let need = bucket_need(req.prompt.len(), req.max_new, &consts);
        let mut target = TargetSession::new(
            be,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;
        let mut tiny = TinySession::new(be)?;

        let mut sw = Stopwatch::new();
        let (logits, _) = target.prefill(&req.prompt, None, kv)?;
        tiny.prefill(&req.prompt, gamma)?;
        stats.prefill_secs = sw.lap();

        let bonus = pick_token(&logits, req.temperature, &mut rng);
        let mut out = SessionOut::new(req.max_new);
        out.push_first(bonus);

        Ok(Box::new(TriForceSession {
            be,
            target,
            tiny,
            pool: kv.pool.clone(),
            out,
            bonus,
            rng,
            stats,
            consts,
            gamma,
            prompt_len: req.prompt.len(),
            temperature: req.temperature,
            phase: Phase::Idle,
            pending: None,
            sw: Stopwatch::new(),
            proposed: 0,
        }))
    }
}

impl TriForceSession<'_> {
    /// Which state buffer the pending plan mutates.
    fn pending_state(&mut self, class: OpClass) -> &mut StateBuf {
        match class {
            OpClass::TinyForward => &mut self.tiny.state,
            _ => &mut self.target.state,
        }
    }

    /// Plan the verification of the drafted chain.
    fn plan_verify(&mut self, chain: &[u32]) -> Result<KernelPlan> {
        let flat = chain_flat(chain, self.consts.tree_t);
        let root_pos = self.prompt_len + self.out.len() - 1;
        self.target.plan_verify_tree(&flat, root_pos)
    }
}

impl EngineSession for TriForceSession<'_> {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::TriForce
    }

    fn is_finished(&self) -> bool {
        self.out.done
    }

    fn emitted(&self) -> usize {
        self.out.len()
    }

    fn step(&mut self) -> Result<StepOutcome> {
        loop {
            match self.drive()? {
                Drive::Complete(o) => return Ok(o),
                Drive::Pending => {
                    let plan = self.pending.take().expect("pending plan after Drive::Pending");
                    let be = self.be;
                    exec_single(be, &plan, self.pending_state(plan.class))?;
                    self.pending = Some(plan);
                }
                Drive::Unsupported => {
                    unreachable!("triforce sessions implement the protocol")
                }
            }
        }
    }

    fn drive(&mut self) -> Result<Drive> {
        loop {
            let phase = std::mem::replace(&mut self.phase, Phase::Idle);
            match phase {
                Phase::Idle => {
                    if self.out.done {
                        return Ok(Drive::Complete(self.out.outcome()));
                    }
                    self.sw = Stopwatch::new();
                    let chain = vec![self.bonus];
                    if self.gamma == 0 {
                        self.stats.draft_secs += self.sw.lap();
                        let plan = self.plan_verify(&chain)?;
                        self.pending = Some(plan);
                        self.phase = Phase::Verify { chain };
                        return Ok(Drive::Pending);
                    }
                    let pos = self.prompt_len + self.out.len() - 1;
                    let plan = self.tiny.plan_step(self.bonus, pos);
                    self.pending = Some(plan);
                    self.phase = Phase::Tiny { g: 0, chain };
                    return Ok(Drive::Pending);
                }
                Phase::Tiny { g, mut chain } => {
                    self.pending = None;
                    let lg = self.tiny.finish_step()?;
                    let cur = pick_token(&lg, self.temperature, &mut self.rng);
                    chain.push(cur);
                    if g + 1 < self.gamma {
                        let pos = self.prompt_len + self.out.len() - 1 + g + 1;
                        let plan = self.tiny.plan_step(cur, pos);
                        self.pending = Some(plan);
                        self.phase = Phase::Tiny { g: g + 1, chain };
                        return Ok(Drive::Pending);
                    }
                    self.stats.draft_secs += self.sw.lap();
                    let plan = self.plan_verify(&chain)?;
                    self.pending = Some(plan);
                    self.phase = Phase::Verify { chain };
                    return Ok(Drive::Pending);
                }
                Phase::Verify { chain } => {
                    self.pending = None;
                    let gamma = self.gamma;
                    let read = self.target.finish_verify_tree(chain.len())?;
                    self.stats.verify_secs += self.sw.lap();

                    // greedy walk down the chain
                    let mut accepted = 0usize;
                    let mut next =
                        pick_token(read.logits(0), self.temperature, &mut self.rng);
                    while accepted < gamma && chain[accepted + 1] == next {
                        accepted += 1;
                        next = pick_token(
                            read.logits(accepted),
                            self.temperature,
                            &mut self.rng,
                        );
                    }
                    self.stats.verify_steps += 1;
                    self.proposed += gamma as u64;
                    self.stats.full_steps += 1;

                    let kept = self.out.push_round(&chain[1..=accepted], next);
                    self.stats.accepted_total += kept;

                    // rejected tiny-cache rows are reused next round
                    self.tiny.rollback(gamma - accepted);

                    let rows: Vec<usize> = (0..=accepted).collect();
                    self.target.cache.set_pending(rows, self.consts.prev_window())?;
                    self.bonus = next;
                    self.stats.other_secs += self.sw.lap();

                    return Ok(Drive::Complete(self.out.outcome()));
                }
            }
        }
    }

    fn take_pending(&mut self) -> Option<(KernelPlan, StateBuf)> {
        let plan = self.pending.take()?;
        let state = std::mem::replace(self.pending_state(plan.class), StateBuf::nil());
        Some((plan, state))
    }

    fn restore_pending(&mut self, state: StateBuf) {
        match &self.phase {
            Phase::Tiny { .. } => self.tiny.state = state,
            _ => self.target.state = state,
        }
    }

    fn spec_observe(&self) -> Option<SpecObservation> {
        Some(SpecObservation {
            proposed: self.proposed,
            committed: self.stats.accepted_total as u64,
            verify_steps: self.stats.verify_steps as u64,
            full_steps: self.stats.full_steps as u64,
            partial_steps: 0,
            refresh_steps: 0,
            context_len: self.prompt_len + self.out.len(),
            depth: self.gamma,
            pv_len: 0,
        })
    }

    fn apply_policy(&mut self, d: &PolicyDirective) {
        // losslessness contract: at temperature > 0 both the tiny-LM
        // draft (γ draws) and the verify walk consume the shared
        // sampling RNG, so a different γ would shift the stream — keep
        // it pinned. At greedy every pick is pure argmax: γ only bounds
        // how far a round reaches, the committed tokens are always the
        // target's greedy continuation.
        if self.temperature > 0.0 {
            return;
        }
        if let Some(depth) = d.draft_depth {
            // the drafted chain is γ+1 tokens padded into the compiled
            // tree window
            let cap = self.consts.tree_t.saturating_sub(1).max(1);
            self.gamma = depth.clamp(1, cap);
        }
    }

    fn finish(self: Box<Self>) -> GenResult {
        let TriForceSession { target, out, mut stats, .. } = *self;
        stats.decode_secs = stats.draft_secs + stats.verify_secs + stats.other_secs;
        stats.new_tokens = out.tokens.len();
        stats.offload_secs = target.offload.secs;
        GenResult { tokens: out.tokens, stats }
    }

    fn state_bytes(&self) -> usize {
        self.target.state_bytes() + self.tiny.state_bytes()
    }

    fn suspend(&mut self) -> Result<Vec<PagedState>> {
        let states = vec![self.target.park(&self.pool)?, self.tiny.park(&self.pool)?];
        self.target.drop_state();
        self.tiny.drop_state();
        Ok(states)
    }

    fn resume(&mut self, states: Vec<PagedState>) -> Result<()> {
        let (mut full, mut tiny) = (false, false);
        for ps in &states {
            match ps.kind {
                StateKind::Full => {
                    self.target.restore_paged(&self.pool, ps)?;
                    full = true;
                }
                StateKind::Tiny => {
                    self.tiny.restore_paged(&self.pool, ps)?;
                    tiny = true;
                }
                k => bail!("unexpected {k:?} block table for a triforce session"),
            }
        }
        if !(full && tiny) {
            bail!("triforce resume needs full + tiny block tables");
        }
        for ps in &states {
            self.pool.free_state(ps);
        }
        Ok(())
    }
}
