//! TriForce-like baseline (Sun et al.): an **independent** tiny draft LM
//! with a streaming (ring) cache proposes a γ-token chain; the target
//! verifies against the full KV cache every step (lossless — TriForce
//! never refreshes a partial target cache).
//!
//! Substitutions vs the original (DESIGN.md §3): the Qwama-0.5B draft is
//! replaced by our 2-layer tiny char-LM trained on the same corpus, and
//! the hierarchical (two-stage) speculation is collapsed into one stage —
//! the properties under test (independent draft, full verification,
//! streaming draft cache) are preserved.

use anyhow::Result;

use crate::config::Config;
use crate::metrics::GenStats;
use crate::model::bucket_need;
use crate::offload::OffloadSim;
use crate::runtime::Runtime;
use crate::sampling::pick_token;
use crate::tokenizer::is_eos;
use crate::tree::{chain_mask, FlatTree};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

use super::session::{TargetSession, TinySession};
use super::{Engine, GenRequest, GenResult};

pub struct TriForceEngine {
    cfg: Config,
}

impl TriForceEngine {
    pub fn new(cfg: Config) -> TriForceEngine {
        TriForceEngine { cfg }
    }
}

/// Flatten a token chain as a degenerate "tree" (row i = depth i).
fn chain_flat(tokens: &[u32], t_pad: usize) -> FlatTree {
    let mut toks = vec![crate::tokenizer::PAD as i32; t_pad];
    let mut depth = vec![0usize; t_pad];
    for (i, &t) in tokens.iter().enumerate() {
        toks[i] = t as i32;
        depth[i] = i;
    }
    FlatTree {
        tokens: toks,
        depth,
        mask: chain_mask(tokens.len(), t_pad),
        n: tokens.len(),
    }
}

impl Engine for TriForceEngine {
    fn kind(&self) -> crate::config::EngineKind {
        crate::config::EngineKind::TriForce
    }

    fn generate(&mut self, rt: &Runtime, req: &GenRequest) -> Result<GenResult> {
        let mut stats = GenStats::default();
        let mut rng = Rng::new(req.seed | 1);
        let consts = rt.manifest.consts.clone();
        let gamma = self.cfg.chain_gamma;
        let need = bucket_need(req.prompt.len(), req.max_new, &consts);
        let mut target = TargetSession::new(
            rt,
            &self.cfg.model_size,
            need,
            OffloadSim::new(self.cfg.offload.clone()),
        )?;
        let mut tiny = TinySession::new(rt)?;

        let mut sw = Stopwatch::new();
        let (logits, _) = target.prefill(&req.prompt, None)?;
        tiny.prefill(&req.prompt, gamma)?;
        stats.prefill_secs = sw.lap();

        let mut out: Vec<u32> = Vec::new();
        let mut bonus = pick_token(&logits, req.temperature, &mut rng);
        out.push(bonus);

        while out.len() < req.max_new && !is_eos(bonus) {
            // --- draft a γ-chain with the tiny LM --------------------------
            let mut chain: Vec<u32> = vec![bonus];
            let mut cur = bonus;
            for g in 0..gamma {
                let pos = req.prompt.len() + out.len() - 1 + g;
                let lg = tiny.step(cur, pos)?;
                cur = pick_token(&lg, req.temperature, &mut rng) as u32;
                chain.push(cur);
            }
            stats.draft_secs += sw.lap();

            // --- target verifies [bonus, d1..dγ] ---------------------------
            let flat = chain_flat(&chain, consts.tree_t);
            let root_pos = req.prompt.len() + out.len() - 1;
            let read = target.verify_tree(&flat, root_pos)?;
            stats.verify_secs += sw.lap();

            // greedy walk down the chain
            let mut accepted = 0usize;
            let mut next = pick_token(read.logits(0), req.temperature, &mut rng);
            while accepted < gamma && chain[accepted + 1] == next {
                accepted += 1;
                next = pick_token(read.logits(accepted), req.temperature, &mut rng);
            }
            stats.verify_steps += 1;
            stats.accepted_total += accepted;
            stats.full_steps += 1;

            for &t in &chain[1..=accepted] {
                out.push(t);
            }
            out.push(next);

            // rejected tiny-cache rows are reused next round
            tiny.rollback(gamma - accepted);

            let rows: Vec<usize> = (0..=accepted).collect();
            target.cache.set_pending(rows, consts.prev_window())?;
            bonus = next;
            stats.other_secs += sw.lap();
        }
        out.truncate(req.max_new); // multi-token acceptance can overshoot
        stats.decode_secs = stats.draft_secs + stats.verify_secs + stats.other_secs;
        stats.new_tokens = out.len();
        stats.offload_secs = target.offload.secs;
        Ok(GenResult { tokens: out, stats })
    }
}
