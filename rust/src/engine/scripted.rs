//! Scripted (model-free) engine sessions: deterministic token streams
//! with no runtime or artifacts behind them. Two uses:
//!
//! * scheduler/server tests — exercise continuous batching, streaming,
//!   cancellation and failure paths without compiled models;
//! * load simulation — drive the coordinator with thousands of synthetic
//!   requests to measure scheduler overhead in isolation.
//!
//! The token stream is lowercase ASCII (`a`, `b`, `c`, …) so decoded
//! output is printable; a session emits one "bonus" token at start (like
//! the real engines' prefill pick) and `tokens_per_step` tokens per
//! `step()` until `max_new`.

use anyhow::{bail, Result};

use crate::config::EngineKind;
use crate::metrics::GenStats;

use super::{
    EngineSession, GenRequest, GenResult, SessionCheckpoint, SessionFactory, SessionOut,
    StepOutcome,
};

fn token_at(i: usize) -> u32 {
    (b'a' + (i % 26) as u8) as u32
}

pub struct ScriptedSession {
    kind: EngineKind,
    out: SessionOut,
    tokens_per_step: usize,
    steps: usize,
    /// inject an engine error on the step with this index (0-based)
    fail_at_step: Option<usize>,
    /// sleep this long per step (simulates device latency; makes
    /// mid-generation cancellation tests deterministic)
    step_micros: u64,
    /// simulated resident state bytes (KV-pool admission tests)
    state_bytes: usize,
    stats: GenStats,
}

impl ScriptedSession {
    pub fn new(
        kind: EngineKind,
        req: &GenRequest,
        tokens_per_step: usize,
        fail_at_step: Option<usize>,
    ) -> ScriptedSession {
        let mut out = SessionOut::new(req.max_new);
        out.push_first(token_at(0));
        let stats = GenStats { prefill_secs: 1e-6, ..GenStats::default() };
        ScriptedSession {
            kind,
            out,
            tokens_per_step: tokens_per_step.max(1),
            steps: 0,
            fail_at_step,
            step_micros: 0,
            state_bytes: 0,
            stats,
        }
    }

    pub fn with_step_micros(mut self, us: u64) -> ScriptedSession {
        self.step_micros = us;
        self
    }

    pub fn with_state_bytes(mut self, bytes: usize) -> ScriptedSession {
        self.state_bytes = bytes;
        self
    }

    /// Rebuild a session at a checkpoint: the scripted stream is
    /// position-indexed, so preloading the emitted tokens and the step
    /// counter continues byte-identically to an undisturbed run.
    pub fn resumed(
        kind: EngineKind,
        req: &GenRequest,
        tokens_per_step: usize,
        ck: &SessionCheckpoint,
    ) -> ScriptedSession {
        let stats = GenStats {
            prefill_secs: 1e-6,
            verify_steps: ck.steps,
            ..GenStats::default()
        };
        ScriptedSession {
            kind,
            out: SessionOut::resumed(req.max_new, ck.emitted.clone()),
            tokens_per_step: tokens_per_step.max(1),
            steps: ck.steps,
            fail_at_step: None,
            step_micros: 0,
            state_bytes: 0,
            stats,
        }
    }
}

impl EngineSession for ScriptedSession {
    fn kind(&self) -> EngineKind {
        self.kind
    }

    fn is_finished(&self) -> bool {
        self.out.done
    }

    fn emitted(&self) -> usize {
        self.out.len()
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if self.fail_at_step == Some(self.steps) {
            bail!("scripted failure at step {}", self.steps);
        }
        if self.step_micros > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.step_micros));
        }
        if !self.out.done {
            // a "round": tokens_per_step-1 drafted + 1 bonus, like a spec
            // engine with a fixed acceptance length
            let base = self.out.len();
            let drafted: Vec<u32> =
                (0..self.tokens_per_step - 1).map(|i| token_at(base + i)).collect();
            let bonus = token_at(base + drafted.len());
            let kept = self.out.push_round(&drafted, bonus);
            self.steps += 1;
            self.stats.verify_steps += 1;
            self.stats.accepted_total += kept;
            self.stats.decode_secs += 1e-6;
        }
        Ok(self.out.outcome())
    }

    fn finish(self: Box<Self>) -> GenResult {
        let ScriptedSession { out, mut stats, .. } = *self;
        stats.new_tokens = out.tokens.len();
        GenResult { tokens: out.tokens, stats }
    }

    // suspend/resume use the trait defaults (a scripted session has no
    // device state to export — only the synthetic pool footprint below)
    fn state_bytes(&self) -> usize {
        self.state_bytes
    }

    fn checkpoint(&self) -> Result<Option<SessionCheckpoint>> {
        if self.out.done {
            return Ok(None);
        }
        Ok(Some(SessionCheckpoint {
            engine: self.kind,
            emitted: self.out.tokens.clone(),
            steps: self.steps,
            size: String::new(),
            bucket: 0,
            data: Vec::new(),
            extra: Vec::new(),
            committed: 0,
            pending: Vec::new(),
            rng: 0,
        }))
    }
}

/// Factory producing [`ScriptedSession`]s — inject into the coordinator
/// (or `server::serve_on`) to test scheduling without artifacts.
#[derive(Debug, Clone)]
pub struct ScriptedFactory {
    /// tokens produced per step (≥ 1)
    pub tokens_per_step: usize,
    /// per-step simulated device latency in microseconds
    pub step_micros: u64,
    /// prompts containing this token fail at `start` (admission-time
    /// engine failure)
    pub fail_start_marker: Option<u32>,
    /// prompts containing this token fail on their first `step()`
    pub fail_step_marker: Option<u32>,
    /// simulated resident bytes per session (reported by both
    /// `estimate_bytes` and the live session — KV-pool admission tests)
    pub session_bytes: usize,
}

impl Default for ScriptedFactory {
    fn default() -> Self {
        ScriptedFactory {
            tokens_per_step: 1,
            step_micros: 0,
            fail_start_marker: None,
            fail_step_marker: None,
            session_bytes: 0,
        }
    }
}

impl SessionFactory<'static> for ScriptedFactory {
    fn start_session(
        &mut self,
        kind: EngineKind,
        req: &GenRequest,
    ) -> Result<Box<dyn EngineSession + 'static>> {
        if let Some(m) = self.fail_start_marker {
            if req.prompt.contains(&m) {
                bail!("scripted start failure");
            }
        }
        let fail_at = self
            .fail_step_marker
            .filter(|m| req.prompt.contains(m))
            .map(|_| 0usize);
        Ok(Box::new(
            ScriptedSession::new(kind, req, self.tokens_per_step, fail_at)
                .with_step_micros(self.step_micros)
                .with_state_bytes(self.session_bytes),
        ))
    }

    fn estimate_bytes(&self, _kind: EngineKind, _req: &GenRequest) -> usize {
        self.session_bytes
    }

    fn start_from_checkpoint(
        &mut self,
        kind: EngineKind,
        req: &GenRequest,
        ck: &SessionCheckpoint,
    ) -> Result<Box<dyn EngineSession + 'static>> {
        Ok(Box::new(
            ScriptedSession::resumed(kind, req, self.tokens_per_step, ck)
                .with_step_micros(self.step_micros)
                .with_state_bytes(self.session_bytes),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_emits_exactly_max_new() {
        let req = GenRequest::greedy(vec![65, 66], 10);
        let mut s: Box<dyn EngineSession> =
            Box::new(ScriptedSession::new(EngineKind::SpecPv, &req, 3, None));
        let mut collected = Vec::new();
        let mut steps = 0;
        while !s.is_finished() {
            let o = s.step().unwrap();
            collected.extend(o.new_tokens);
            steps += 1;
            assert!(steps < 100, "did not terminate");
        }
        assert_eq!(collected.len(), 10);
        let r = s.finish();
        assert_eq!(r.tokens, collected);
        assert_eq!(r.stats.new_tokens, 10);
        // 1 at start + 3/step → steps = ceil(9/3) = 3
        assert_eq!(steps, 3);
    }

    #[test]
    fn scripted_failure_injection() {
        let req = GenRequest::greedy(vec![1], 10);
        let mut s = ScriptedSession::new(EngineKind::SpecPv, &req, 1, Some(1));
        assert!(s.step().is_ok());
        assert!(s.step().is_err());
    }

    #[test]
    fn checkpoint_resume_continues_byte_identically() {
        let req = GenRequest::greedy(vec![65, 66], 12);
        // undisturbed reference run
        let mut s = ScriptedSession::new(EngineKind::SpecPv, &req, 3, None);
        let mut reference = Vec::new();
        while !s.is_finished() {
            reference.extend(s.step().unwrap().new_tokens);
        }
        let reference = Box::new(s).finish().tokens;
        assert_eq!(reference.len(), 12);

        // checkpoint after two steps, resume in a fresh session
        let mut s = ScriptedSession::new(EngineKind::SpecPv, &req, 3, None);
        let mut streamed = s.out.outcome().new_tokens; // prefill token
        streamed.extend(s.step().unwrap().new_tokens);
        streamed.extend(s.step().unwrap().new_tokens);
        let ck = s.checkpoint().unwrap().expect("mid-flight checkpoint");
        assert_eq!(ck.emitted, streamed);
        let mut r = ScriptedSession::resumed(EngineKind::SpecPv, &req, 3, &ck);
        while !r.is_finished() {
            streamed.extend(r.step().unwrap().new_tokens);
        }
        assert_eq!(streamed, reference);
        assert_eq!(Box::new(r).finish().tokens, reference);
    }

    #[test]
    fn factory_markers() {
        let mut f = ScriptedFactory {
            fail_start_marker: Some(999),
            ..ScriptedFactory::default()
        };
        assert!(f
            .start_session(EngineKind::SpecPv, &GenRequest::greedy(vec![999], 4))
            .is_err());
        assert!(f
            .start_session(EngineKind::SpecPv, &GenRequest::greedy(vec![1], 4))
            .is_ok());
    }
}
