//! Scripted (model-free) engine sessions: deterministic token streams
//! with no runtime or artifacts behind them. Two uses:
//!
//! * scheduler/server tests — exercise continuous batching, streaming,
//!   cancellation and failure paths without compiled models;
//! * load simulation — drive the coordinator with thousands of synthetic
//!   requests to measure scheduler overhead in isolation.
//!
//! The token stream is lowercase ASCII (`a`, `b`, `c`, …) so decoded
//! output is printable; a session emits one "bonus" token at start (like
//! the real engines' prefill pick) and `tokens_per_step` tokens per
//! `step()` until `max_new`.

use anyhow::{bail, Result};

use crate::config::EngineKind;
use crate::metrics::GenStats;
use crate::policy::{PolicyDirective, SpecObservation};

use super::{
    EngineSession, GenRequest, GenResult, SessionCheckpoint, SessionFactory, SessionOut,
    StepOutcome,
};

fn token_at(i: usize) -> u32 {
    (b'a' + (i % 26) as u8) as u32
}

/// Scripted speculation dynamics: a deterministic acceptance stream plus
/// a virtual-time cost model, so scheduler tests and `bench policy` can
/// exercise the adaptive policy loop (DESIGN.md §16) without models or
/// wall clocks.
///
/// Each round the session drafts `depth` tokens; the acceptance ceiling
/// for the round is `accepts[round % accepts.len()]`, optionally decayed
/// by drift (`rounds_since_refresh / decay_every`), and the round commits
/// `min(depth, ceiling)` drafted tokens plus one bonus. Costs are
/// *virtual* — they accrue to `GenStats::decode_secs` without sleeping —
/// so simulated tok/s is a pure function of the policy's decisions.
#[derive(Debug, Clone)]
pub struct SpecSim {
    /// per-round acceptance ceilings, cycled
    pub accepts: Vec<usize>,
    /// every N partial rounds since the last refresh the ceiling drops
    /// by one (0 = no drift)
    pub decay_every: usize,
    /// initial draft depth
    pub depth: usize,
    /// fixed refresh cadence in rounds (0 = drift/policy only)
    pub refresh_every: usize,
    /// virtual cost per drafted token (µs)
    pub draft_us: f64,
    /// virtual cost per verification round (µs)
    pub verify_us: f64,
    /// virtual cost of a full-verification refresh (µs)
    pub refresh_us: f64,
}

impl Default for SpecSim {
    fn default() -> Self {
        SpecSim {
            accepts: vec![4],
            decay_every: 0,
            depth: 4,
            refresh_every: 0,
            draft_us: 10.0,
            verify_us: 100.0,
            refresh_us: 400.0,
        }
    }
}

/// Live speculation state for one scripted session driven by a [`SpecSim`].
#[derive(Debug, Clone)]
struct SpecSimState {
    sim: SpecSim,
    depth: usize,
    round: usize,
    rounds_since_refresh: usize,
    force_refresh: bool,
    proposed: u64,
    committed: u64,
    partial_steps: u64,
    refresh_steps: u64,
}

impl SpecSimState {
    fn new(sim: SpecSim) -> SpecSimState {
        let depth = sim.depth.max(1);
        SpecSimState {
            sim,
            depth,
            round: 0,
            rounds_since_refresh: 0,
            force_refresh: false,
            proposed: 0,
            committed: 0,
            partial_steps: 0,
            refresh_steps: 0,
        }
    }

    /// Whether this sim models a refreshable partial state at all: with
    /// no drift decay and no fixed cadence a refresh restores nothing, so
    /// the session reports no partial rounds and `pv_len = 0` (a pure
    /// acceptance simulator, like a non-SpecPV engine).
    fn models_refresh(&self) -> bool {
        self.sim.decay_every > 0 || self.sim.refresh_every > 0
    }

    /// Acceptance ceiling for the current round after drift decay.
    fn ceiling(&self) -> usize {
        let base = self.sim.accepts[self.round % self.sim.accepts.len()];
        if self.sim.decay_every == 0 {
            base
        } else {
            base.saturating_sub(self.rounds_since_refresh / self.sim.decay_every)
        }
    }
}

pub struct ScriptedSession {
    kind: EngineKind,
    out: SessionOut,
    tokens_per_step: usize,
    steps: usize,
    /// inject an engine error on the step with this index (0-based)
    fail_at_step: Option<usize>,
    /// sleep this long per step (simulates device latency; makes
    /// mid-generation cancellation tests deterministic)
    step_micros: u64,
    /// simulated resident state bytes (KV-pool admission tests)
    state_bytes: usize,
    /// scripted speculation dynamics (policy-loop tests and `bench policy`)
    spec: Option<SpecSimState>,
    stats: GenStats,
}

impl ScriptedSession {
    pub fn new(
        kind: EngineKind,
        req: &GenRequest,
        tokens_per_step: usize,
        fail_at_step: Option<usize>,
    ) -> ScriptedSession {
        let mut out = SessionOut::new(req.max_new);
        out.push_first(token_at(0));
        let stats = GenStats { prefill_secs: 1e-6, ..GenStats::default() };
        ScriptedSession {
            kind,
            out,
            tokens_per_step: tokens_per_step.max(1),
            steps: 0,
            fail_at_step,
            step_micros: 0,
            state_bytes: 0,
            spec: None,
            stats,
        }
    }

    pub fn with_step_micros(mut self, us: u64) -> ScriptedSession {
        self.step_micros = us;
        self
    }

    pub fn with_state_bytes(mut self, bytes: usize) -> ScriptedSession {
        self.state_bytes = bytes;
        self
    }

    /// Drive the session by a [`SpecSim`] acceptance stream instead of
    /// the fixed `tokens_per_step` cadence.
    pub fn with_spec(mut self, sim: SpecSim) -> ScriptedSession {
        self.spec = Some(SpecSimState::new(sim));
        self
    }

    /// Rebuild a session at a checkpoint: the scripted stream is
    /// position-indexed, so preloading the emitted tokens and the step
    /// counter continues byte-identically to an undisturbed run.
    pub fn resumed(
        kind: EngineKind,
        req: &GenRequest,
        tokens_per_step: usize,
        ck: &SessionCheckpoint,
    ) -> ScriptedSession {
        let stats = GenStats {
            prefill_secs: 1e-6,
            verify_steps: ck.steps,
            ..GenStats::default()
        };
        ScriptedSession {
            kind,
            out: SessionOut::resumed(req.max_new, ck.emitted.clone()),
            tokens_per_step: tokens_per_step.max(1),
            steps: ck.steps,
            fail_at_step: None,
            step_micros: 0,
            state_bytes: 0,
            spec: None,
            stats,
        }
    }

    /// One speculation round under the [`SpecSim`] dynamics: refresh if
    /// due (fixed cadence or policy-forced), then commit
    /// `min(depth, ceiling)` drafted tokens + 1 bonus at virtual cost.
    fn spec_round(&mut self) {
        let s = self.spec.as_mut().expect("spec_round without SpecSim");
        let mut cost_us = 0.0;
        if s.models_refresh() {
            let refresh_due = s.force_refresh
                || (s.sim.refresh_every > 0
                    && s.rounds_since_refresh >= s.sim.refresh_every);
            if refresh_due {
                s.force_refresh = false;
                s.rounds_since_refresh = 0;
                s.refresh_steps += 1;
                self.stats.full_steps += 1;
                cost_us += s.sim.refresh_us;
            } else {
                s.partial_steps += 1;
            }
        } else {
            s.force_refresh = false;
        }
        let accepted = s.depth.min(s.ceiling());
        s.round += 1;
        s.rounds_since_refresh += 1;
        s.proposed += s.depth as u64;
        cost_us += s.depth as f64 * s.sim.draft_us + s.sim.verify_us;

        let base = self.out.len();
        let drafted: Vec<u32> = (0..accepted).map(|i| token_at(base + i)).collect();
        let bonus = token_at(base + drafted.len());
        let kept = self.out.push_round(&drafted, bonus);
        let s = self.spec.as_mut().expect("spec state");
        s.committed += kept.saturating_sub(1) as u64;
        self.steps += 1;
        self.stats.verify_steps += 1;
        self.stats.accepted_total += kept;
        self.stats.decode_secs += cost_us * 1e-6;
    }
}

impl EngineSession for ScriptedSession {
    fn kind(&self) -> EngineKind {
        self.kind
    }

    fn is_finished(&self) -> bool {
        self.out.done
    }

    fn emitted(&self) -> usize {
        self.out.len()
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if self.fail_at_step == Some(self.steps) {
            bail!("scripted failure at step {}", self.steps);
        }
        if self.step_micros > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.step_micros));
        }
        if !self.out.done {
            if self.spec.is_some() {
                self.spec_round();
            } else {
                // a "round": tokens_per_step-1 drafted + 1 bonus, like a
                // spec engine with a fixed acceptance length
                let base = self.out.len();
                let drafted: Vec<u32> =
                    (0..self.tokens_per_step - 1).map(|i| token_at(base + i)).collect();
                let bonus = token_at(base + drafted.len());
                let kept = self.out.push_round(&drafted, bonus);
                self.steps += 1;
                self.stats.verify_steps += 1;
                self.stats.accepted_total += kept;
                self.stats.decode_secs += 1e-6;
            }
        }
        Ok(self.out.outcome())
    }

    fn finish(self: Box<Self>) -> GenResult {
        let ScriptedSession { out, mut stats, .. } = *self;
        stats.new_tokens = out.tokens.len();
        GenResult { tokens: out.tokens, stats }
    }

    // suspend/resume use the trait defaults (a scripted session has no
    // device state to export — only the synthetic pool footprint below)
    fn state_bytes(&self) -> usize {
        self.state_bytes
    }

    fn spec_observe(&self) -> Option<SpecObservation> {
        let s = self.spec.as_ref()?;
        Some(SpecObservation {
            proposed: s.proposed,
            committed: s.committed,
            verify_steps: self.stats.verify_steps as u64,
            full_steps: self.stats.full_steps as u64,
            partial_steps: s.partial_steps,
            refresh_steps: s.refresh_steps,
            context_len: self.out.len(),
            depth: s.depth,
            // rounds since the last full verify stand in for the pv
            // chain length: non-zero exactly when a refresh would do work
            pv_len: if s.models_refresh() { s.rounds_since_refresh } else { 0 },
        })
    }

    fn apply_policy(&mut self, d: &PolicyDirective) {
        let Some(s) = self.spec.as_mut() else { return };
        if let Some(depth) = d.draft_depth {
            s.depth = depth.max(1);
        }
        if d.force_refresh {
            s.force_refresh = true;
        }
    }

    fn checkpoint(&self) -> Result<Option<SessionCheckpoint>> {
        if self.out.done {
            return Ok(None);
        }
        Ok(Some(SessionCheckpoint {
            engine: self.kind,
            emitted: self.out.tokens.clone(),
            steps: self.steps,
            size: String::new(),
            bucket: 0,
            data: Vec::new(),
            extra: Vec::new(),
            committed: 0,
            pending: Vec::new(),
            rng: 0,
            policy: None,
        }))
    }
}

/// Factory producing [`ScriptedSession`]s — inject into the coordinator
/// (or `server::serve_on`) to test scheduling without artifacts.
#[derive(Debug, Clone)]
pub struct ScriptedFactory {
    /// tokens produced per step (≥ 1)
    pub tokens_per_step: usize,
    /// per-step simulated device latency in microseconds
    pub step_micros: u64,
    /// prompts containing this token fail at `start` (admission-time
    /// engine failure)
    pub fail_start_marker: Option<u32>,
    /// prompts containing this token fail on their first `step()`
    pub fail_step_marker: Option<u32>,
    /// simulated resident bytes per session (reported by both
    /// `estimate_bytes` and the live session — KV-pool admission tests)
    pub session_bytes: usize,
    /// when set, sessions run the [`SpecSim`] acceptance stream instead
    /// of the fixed `tokens_per_step` cadence
    pub spec: Option<SpecSim>,
}

impl Default for ScriptedFactory {
    fn default() -> Self {
        ScriptedFactory {
            tokens_per_step: 1,
            step_micros: 0,
            fail_start_marker: None,
            fail_step_marker: None,
            session_bytes: 0,
            spec: None,
        }
    }
}

impl SessionFactory<'static> for ScriptedFactory {
    fn start_session(
        &mut self,
        kind: EngineKind,
        req: &GenRequest,
    ) -> Result<Box<dyn EngineSession + 'static>> {
        if let Some(m) = self.fail_start_marker {
            if req.prompt.contains(&m) {
                bail!("scripted start failure");
            }
        }
        let fail_at = self
            .fail_step_marker
            .filter(|m| req.prompt.contains(m))
            .map(|_| 0usize);
        let mut s = ScriptedSession::new(kind, req, self.tokens_per_step, fail_at)
            .with_step_micros(self.step_micros)
            .with_state_bytes(self.session_bytes);
        if let Some(sim) = &self.spec {
            s = s.with_spec(sim.clone());
        }
        Ok(Box::new(s))
    }

    fn estimate_bytes(&self, _kind: EngineKind, _req: &GenRequest) -> usize {
        self.session_bytes
    }

    fn start_from_checkpoint(
        &mut self,
        kind: EngineKind,
        req: &GenRequest,
        ck: &SessionCheckpoint,
    ) -> Result<Box<dyn EngineSession + 'static>> {
        let mut s = ScriptedSession::resumed(kind, req, self.tokens_per_step, ck)
            .with_step_micros(self.step_micros)
            .with_state_bytes(self.session_bytes);
        if let Some(sim) = &self.spec {
            // sim counters restart at zero; the coordinator's restored
            // PolicyState resets its delta base to match (DESIGN.md §16)
            s = s.with_spec(sim.clone());
        }
        Ok(Box::new(s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_emits_exactly_max_new() {
        let req = GenRequest::greedy(vec![65, 66], 10);
        let mut s: Box<dyn EngineSession> =
            Box::new(ScriptedSession::new(EngineKind::SpecPv, &req, 3, None));
        let mut collected = Vec::new();
        let mut steps = 0;
        while !s.is_finished() {
            let o = s.step().unwrap();
            collected.extend(o.new_tokens);
            steps += 1;
            assert!(steps < 100, "did not terminate");
        }
        assert_eq!(collected.len(), 10);
        let r = s.finish();
        assert_eq!(r.tokens, collected);
        assert_eq!(r.stats.new_tokens, 10);
        // 1 at start + 3/step → steps = ceil(9/3) = 3
        assert_eq!(steps, 3);
    }

    #[test]
    fn scripted_failure_injection() {
        let req = GenRequest::greedy(vec![1], 10);
        let mut s = ScriptedSession::new(EngineKind::SpecPv, &req, 1, Some(1));
        assert!(s.step().is_ok());
        assert!(s.step().is_err());
    }

    #[test]
    fn checkpoint_resume_continues_byte_identically() {
        let req = GenRequest::greedy(vec![65, 66], 12);
        // undisturbed reference run
        let mut s = ScriptedSession::new(EngineKind::SpecPv, &req, 3, None);
        let mut reference = Vec::new();
        while !s.is_finished() {
            reference.extend(s.step().unwrap().new_tokens);
        }
        let reference = Box::new(s).finish().tokens;
        assert_eq!(reference.len(), 12);

        // checkpoint after two steps, resume in a fresh session
        let mut s = ScriptedSession::new(EngineKind::SpecPv, &req, 3, None);
        let mut streamed = s.out.outcome().new_tokens; // prefill token
        streamed.extend(s.step().unwrap().new_tokens);
        streamed.extend(s.step().unwrap().new_tokens);
        let ck = s.checkpoint().unwrap().expect("mid-flight checkpoint");
        assert_eq!(ck.emitted, streamed);
        let mut r = ScriptedSession::resumed(EngineKind::SpecPv, &req, 3, &ck);
        while !r.is_finished() {
            streamed.extend(r.step().unwrap().new_tokens);
        }
        assert_eq!(streamed, reference);
        assert_eq!(Box::new(r).finish().tokens, reference);
    }

    #[test]
    fn spec_sim_acceptance_stream_and_directives() {
        let req = GenRequest::greedy(vec![1], 200);
        let sim = SpecSim {
            accepts: vec![4],
            depth: 4,
            refresh_every: 3,
            ..SpecSim::default()
        };
        let mut s = ScriptedSession::new(EngineKind::SpecPv, &req, 1, None)
            .with_spec(sim.clone());
        for _ in 0..6 {
            s.step().unwrap();
        }
        let o = s.spec_observe().unwrap();
        assert_eq!(o.proposed, 24, "6 rounds × depth 4");
        assert_eq!(o.committed, 24, "ceiling = depth → every draft accepted");
        assert_eq!(o.refresh_steps, 1, "fixed cadence fires once in 6 rounds");
        assert_eq!(o.partial_steps, 5);
        assert_eq!(o.depth, 4);

        // a depth directive takes effect on the next round
        s.apply_policy(&PolicyDirective { draft_depth: Some(2), force_refresh: false });
        s.step().unwrap();
        let o2 = s.spec_observe().unwrap();
        assert_eq!(o2.proposed - o.proposed, 2);
        assert_eq!(o2.committed - o.committed, 2);

        // a forced refresh fires exactly once, then the flag clears
        let before = s.spec_observe().unwrap().refresh_steps;
        s.apply_policy(&PolicyDirective { draft_depth: None, force_refresh: true });
        s.step().unwrap();
        s.step().unwrap();
        assert_eq!(s.spec_observe().unwrap().refresh_steps, before + 1);

        // byte-determinism: an identical run emits the identical stream
        let mut a = ScriptedSession::new(EngineKind::SpecPv, &req, 1, None)
            .with_spec(sim.clone());
        let mut b = ScriptedSession::new(EngineKind::SpecPv, &req, 1, None)
            .with_spec(sim);
        while !a.is_finished() {
            assert_eq!(
                a.step().unwrap().new_tokens,
                b.step().unwrap().new_tokens
            );
        }
        assert!(b.is_finished());
    }

    #[test]
    fn spec_sim_drift_decays_acceptance_until_refresh() {
        let req = GenRequest::greedy(vec![1], 400);
        let sim = SpecSim {
            accepts: vec![4],
            depth: 4,
            decay_every: 2,
            refresh_every: 0,
            ..SpecSim::default()
        };
        let mut s =
            ScriptedSession::new(EngineKind::SpecPv, &req, 1, None).with_spec(sim);
        // rounds 0..6: ceiling decays 4,4,3,3,2,2 as drift accumulates
        let mut kept = Vec::new();
        for _ in 0..6 {
            let before = s.emitted();
            s.step().unwrap();
            kept.push(s.emitted() - before - 1);
        }
        assert_eq!(kept, vec![4, 4, 3, 3, 2, 2]);
        // a refresh restores the ceiling
        s.apply_policy(&PolicyDirective { draft_depth: None, force_refresh: true });
        let before = s.emitted();
        s.step().unwrap();
        assert_eq!(s.emitted() - before - 1, 4);
    }

    #[test]
    fn factory_markers() {
        let mut f = ScriptedFactory {
            fail_start_marker: Some(999),
            ..ScriptedFactory::default()
        };
        assert!(f
            .start_session(EngineKind::SpecPv, &GenRequest::greedy(vec![999], 4))
            .is_err());
        assert!(f
            .start_session(EngineKind::SpecPv, &GenRequest::greedy(vec![1], 4))
            .is_ok());
    }
}
