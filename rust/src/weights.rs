//! Reader for the `artifacts/weights_*.bin` format written by
//! `python/compile/train.py::save_weights`:
//!
//! ```text
//! magic "SPVW" | u32 version | u32 n_tensors
//! per tensor: u16 name_len | name | u8 ndim | u32 dims[ndim] | f32 data
//! ```
//! All integers little-endian, data row-major f32.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn len(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// A named tensor collection (BTreeMap: iteration order == the sorted
/// order the AOT manifest records for executable weight arguments).
#[derive(Debug, Default)]
pub struct Weights {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn load(path: &Path) -> Result<Weights> {
        let buf = fs::read(path)
            .with_context(|| format!("reading weights {path:?}"))?;
        Self::parse(&buf).with_context(|| format!("parsing {path:?}"))
    }

    pub fn parse(buf: &[u8]) -> Result<Weights> {
        let mut r = Reader { buf, off: 0 };
        if r.bytes(4)? != b"SPVW" {
            bail!("bad magic (not a SPVW weights file)");
        }
        let version = r.u32()?;
        if version != 1 {
            bail!("unsupported weights version {version}");
        }
        let n = r.u32()? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name_len = r.u16()? as usize;
            let name = String::from_utf8(r.bytes(name_len)?.to_vec())?;
            let ndim = r.u8()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.u32()? as usize);
            }
            let count: usize = dims.iter().product::<usize>().max(1);
            let raw = r.bytes(count * 4)?;
            let mut data = vec![0f32; count];
            for (i, ch) in raw.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
            }
            tensors.insert(name.clone(), Tensor { name, dims, data });
        }
        if r.off != buf.len() {
            bail!("{} trailing bytes after last tensor", buf.len() - r.off);
        }
        Ok(Weights { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing weight tensor '{name}'"))
    }

    /// Tensor names with the given prefix, sorted (== python `sorted()`).
    pub fn names_with_prefix(&self, prefix: &str) -> Vec<&str> {
        self.tensors
            .keys()
            .filter(|k| k.starts_with(prefix))
            .map(|s| s.as_str())
            .collect()
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.off + n > self.buf.len() {
            bail!(
                "truncated weights file (want {n} bytes at {}, have {})",
                self.off,
                self.buf.len() - self.off
            );
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        // two tensors: "a" scalar-ish [2], "b" [2,3]
        let mut v = Vec::new();
        v.extend(b"SPVW");
        v.extend(1u32.to_le_bytes());
        v.extend(2u32.to_le_bytes());
        // tensor a
        v.extend(1u16.to_le_bytes());
        v.extend(b"a");
        v.push(1);
        v.extend(2u32.to_le_bytes());
        for x in [1.0f32, 2.0] {
            v.extend(x.to_le_bytes());
        }
        // tensor b
        v.extend(1u16.to_le_bytes());
        v.extend(b"b");
        v.push(2);
        v.extend(2u32.to_le_bytes());
        v.extend(3u32.to_le_bytes());
        for x in [0.5f32, -0.5, 1.5, -1.5, 2.5, -2.5] {
            v.extend(x.to_le_bytes());
        }
        v
    }

    #[test]
    fn parse_ok() {
        let w = Weights::parse(&sample()).unwrap();
        assert_eq!(w.tensors.len(), 2);
        assert_eq!(w.get("a").unwrap().data, vec![1.0, 2.0]);
        assert_eq!(w.get("b").unwrap().dims, vec![2, 3]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = sample();
        b[0] = b'X';
        assert!(Weights::parse(&b).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let b = sample();
        for cut in [3, 10, b.len() - 1] {
            assert!(Weights::parse(&b[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn rejects_trailing() {
        let mut b = sample();
        b.push(0);
        assert!(Weights::parse(&b).is_err());
    }

    #[test]
    fn prefix_listing_sorted() {
        let w = Weights::parse(&sample()).unwrap();
        assert_eq!(w.names_with_prefix(""), vec!["a", "b"]);
    }
}
