//! `cargo bench` entry point that regenerates the paper's tables/figures
//! in quick mode through the experiment harness (full runs:
//! `specpv bench all --out results`). Skips gracefully when artifacts are
//! missing so `cargo bench` works in a fresh checkout.

use std::path::{Path, PathBuf};

use specpv::config::Config;
use specpv::harness;
use specpv::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/ not built — run `make artifacts` first; skipping");
        return Ok(());
    }
    let cfg = Config { artifacts_dir: dir.clone(), ..Config::default() };
    let rt = Runtime::new(&dir)?;
    let out = PathBuf::from("results/bench_quick");
    for id in ["fig1", "table1", "table4", "fig6", "fig8"] {
        println!("=== {id} (quick) ===");
        harness::run_experiment(&rt, &cfg, id, &out, true)?;
    }
    let c = rt.counters.borrow();
    println!(
        "[runtime totals: {} executions {:.1}s, {} compiles {:.1}s]",
        c.executions, c.exec_secs, c.compilations, c.compile_secs
    );
    Ok(())
}
