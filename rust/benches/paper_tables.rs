//! `cargo bench` entry point that regenerates the paper's tables/figures
//! in quick mode through the experiment harness (full runs:
//! `specpv bench all --out results`). Runs on the AOT artifacts when
//! present, otherwise on the pure-Rust reference backend (fig8 needs the
//! build-time train log and self-skips without it).

use std::path::{Path, PathBuf};

use specpv::backend::{self, Backend};
use specpv::config::Config;
use specpv::harness;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let cfg = Config { artifacts_dir: dir.clone(), ..Config::default() };
    let be = backend::from_config(&cfg)?;
    println!("[{} backend]", be.name());
    let out = PathBuf::from("results/bench_quick");
    for id in ["fig1", "table1", "table4", "fig6", "fig8"] {
        println!("=== {id} (quick) ===");
        harness::run_experiment(be.as_ref(), &cfg, id, &out, true)?;
    }
    let c = be.counters();
    println!(
        "[backend totals: {} executions {:.1}s, {} compiles {:.1}s]",
        c.executions, c.exec_secs, c.compilations, c.compile_secs
    );
    Ok(())
}
