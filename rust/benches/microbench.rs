//! Micro-benchmarks of the L3 hot paths (run via `cargo bench`).
//! Criterion is not available offline; this uses the in-repo harness
//! (`specpv::bench::measure`) and prints mean/p50 per operation.
//! These are the pure-rust costs that sit *between* executable calls on
//! the decode path — they must stay ≪ 1 ms so the coordinator is never
//! the bottleneck (DESIGN.md §9 L3 target).

use specpv::bench::measure;
use specpv::config::SpecPvConfig;
use specpv::retrieval::plan_gather;
use specpv::sampling::{log_softmax, top_k};
use specpv::tree::Tree;
use specpv::util::rng::Rng;
use specpv::{corpus, json::Json, metrics};

fn report(name: &str, iters: usize, s: &specpv::util::stats::Samples) {
    println!(
        "{name:40} {:>10.1} us/iter  (p50 {:>8.1} us, {iters} iters)",
        s.mean() * 1e6,
        s.p50() * 1e6
    );
}

fn main() -> anyhow::Result<()> {
    println!("== L3 micro-benchmarks ==");

    // draft-tree build + flatten + mask (per decode round)
    let mut rng = Rng::new(7);
    let s = measure(10, 2000, || {
        let mut t = Tree::new(65);
        for _ in 0..12 {
            let p = rng.below(t.len());
            t.add(p, rng.below(320) as u32, -0.3);
        }
        let t = t.prune_top(16);
        let f = t.flatten(16);
        std::hint::black_box(f);
        Ok(())
    })?;
    report("tree build+prune+flatten(16)", 2000, &s);

    // retrieval planning over a 8192-token cache (256 blocks, 4 layers)
    let scores: Vec<f32> = (0..4 * 3 * 256).map(|i| (i % 97) as f32).collect();
    let cfg = SpecPvConfig::default();
    let s = measure(10, 2000, || {
        let plan = plan_gather(&scores, 4, 256, 32, 8100, 24, &cfg);
        std::hint::black_box(plan);
        Ok(())
    })?;
    report("retrieval plan_gather(256 blocks)", 2000, &s);

    // logits post-processing per verify step (16 rows of vocab 320)
    let logits: Vec<f32> = (0..320).map(|i| (i as f32 * 0.37).sin()).collect();
    let s = measure(10, 2000, || {
        for _ in 0..16 {
            std::hint::black_box(top_k(&logits, 4));
        }
        std::hint::black_box(log_softmax(&logits));
        Ok(())
    })?;
    report("per-step logits topk+softmax", 2000, &s);

    // refresh mask construction (t=64)
    let mut t = Tree::new(1);
    for i in 0..12 {
        t.add(i % (i + 1), 2, -0.1);
    }
    let flat = t.flatten(16);
    let s = measure(10, 2000, || {
        std::hint::black_box(specpv::tree::refresh_mask(40, &flat, 64));
        Ok(())
    })?;
    report("refresh_mask(40+16 -> 64)", 2000, &s);

    // metrics on ~1KB texts (per-result cost in quality harnesses)
    let a = corpus::novel_text(1, 1000);
    let b = corpus::novel_text(2, 1000);
    let s = measure(5, 200, || {
        std::hint::black_box(metrics::rouge_l(&a, &b));
        Ok(())
    })?;
    report("rouge_l(1KB, 1KB)", 200, &s);

    let s = measure(5, 200, || {
        std::hint::black_box(metrics::bleurt_proxy(&a, &b));
        Ok(())
    })?;
    report("bleurt_proxy(1KB, 1KB)", 200, &s);

    // JSON protocol round-trip (per server request)
    let req = Json::obj()
        .set("op", "generate")
        .set("prompt", a.as_str())
        .set("max_new", 128usize);
    let txt = req.to_string();
    let s = measure(10, 1000, || {
        std::hint::black_box(Json::parse(&txt)?);
        Ok(())
    })?;
    report("json parse 1KB request", 1000, &s);

    // corpus generation (workload-gen cost in benches)
    let s = measure(2, 50, || {
        std::hint::black_box(corpus::continuation_prompt(3, 4096));
        Ok(())
    })?;
    report("corpus novel_text(4KB)", 50, &s);

    Ok(())
}
