//! Paged block-pool acceptance suite:
//!
//!   * property test — arbitrary seeded sequences of allocate / free /
//!     share / copy-on-write / spill-and-promote over `KvPool` read back
//!     byte-identically against a flat-slab oracle (the plain `Vec<f32>`
//!     image each block table is supposed to represent);
//!   * backend round-trip — `park_state` → `unpark_state` through the
//!     pool reproduces exactly the image `export_state` reports for a
//!     real prefilled reference-backend state;
//!   * swap-fault recovery — corrupting the spill files of a preempted
//!     session makes resume fail **cleanly**: the request is re-queued
//!     and regenerated from scratch with identical output, the registry
//!     counts a swap fault, and nothing panics;
//!   * boot-epoch isolation — spill pages written by one process
//!     incarnation are GC'd on the next boot and can never be resolved
//!     by it (DESIGN.md §17).

use std::path::PathBuf;

use specpv::backend::reference::ReferenceBackend;
use specpv::backend::{Backend, StateKind};
use specpv::config::{BackendKind, Config, EngineKind, KvQuant};
use specpv::coordinator::{Coordinator, Event, SubmitOpts};
use specpv::corpus;
use specpv::engine::{self, GenRequest};
use specpv::kvstore::{KvCtx, KvPool, PagedState};
use specpv::offload::OffloadSim;
use specpv::tokenizer;

/// Deterministic xorshift64* generator — the property test must replay
/// exactly from its seed.
struct XorShift(u64);

impl XorShift {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n.max(1) as u64) as usize
    }

    /// Exactly-representable values, with a bias toward zero so the
    /// all-zero page fast path gets exercised.
    fn val(&mut self) -> f32 {
        match self.below(4) {
            0 => 0.0,
            _ => (self.below(2048) as f32) - 1024.0,
        }
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Recursively walk `dir` for spill pages (`*.kvp`); spills live under
/// per-boot epoch subdirectories (`epoch-<E>/p<N>`, swap.rs). Returns
/// the page-file count; with `clobber` set, overwrites each with junk.
fn walk_spill_pages(dir: &std::path::Path, clobber: bool) -> usize {
    let mut n = 0;
    let Ok(rd) = std::fs::read_dir(dir) else { return 0 };
    for f in rd.flatten() {
        let p = f.path();
        if p.is_dir() {
            n += walk_spill_pages(&p, clobber);
        } else if p.extension().map(|e| e == "kvp").unwrap_or(false) {
            if clobber {
                std::fs::write(&p, b"corrupt").unwrap();
            }
            n += 1;
        }
    }
    n
}

fn clobber_spill_pages(dir: &std::path::Path) -> usize {
    walk_spill_pages(dir, true)
}

/// The f32 element of a flat image (`data ++ extra`) at global index
/// `g`, as the oracle sees it.
fn image_get(data: &[f32], extra: &[f32], g: usize) -> f32 {
    if g < data.len() {
        data[g]
    } else {
        extra[g - data.len()]
    }
}

fn image_set(data: &mut [f32], extra: &mut [f32], g: usize, v: f32) {
    if g < data.len() {
        data[g] = v;
    } else {
        extra[g - data.len()] = v;
    }
}

fn assert_round_trip(pool: &KvPool, data: &[f32], extra: &[f32], ps: &PagedState, ctx: &str) {
    let (d, e) = pool.read_image(ps).unwrap();
    let same = d.len() == data.len()
        && e.len() == extra.len()
        && d.iter().zip(data).all(|(a, b)| a.to_bits() == b.to_bits())
        && e.iter().zip(extra).all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(same, "{ctx}: paged read-back diverged from the flat-slab oracle");
}

#[test]
fn arbitrary_pool_op_sequences_round_trip_byte_identically() {
    for seed in [1u64, 42, 0xdecafbad] {
        let dir = tmp_dir(&format!("pool_prop_{seed}"));
        // tiny pages so multi-page tables are cheap; the exact (f32)
        // tier only — int8 is tolerance-bounded, not byte-identical
        let pool = KvPool::with_opts(0, 64, Some(&dir), KvQuant::None);
        let pe = pool.page_elems();
        let mut rng = XorShift(seed | 1);
        // the oracle: each live block table alongside the flat image it
        // must keep representing
        let mut live: Vec<(Vec<f32>, Vec<f32>, PagedState)> = Vec::new();

        for step in 0..300 {
            match rng.below(6) {
                // allocate a fresh multi-page state
                0 | 1 => {
                    let dl = 1 + rng.below(3 * pe);
                    let el = rng.below(pe);
                    let data: Vec<f32> = (0..dl).map(|_| rng.val()).collect();
                    let extra: Vec<f32> = (0..el).map(|_| rng.val()).collect();
                    let ps = pool.park_image(StateKind::Full, "s", 64, &data, &extra);
                    live.push((data, extra, ps));
                }
                // free one reference
                2 => {
                    if !live.is_empty() {
                        let (_, _, ps) = live.swap_remove(rng.below(live.len()));
                        pool.free_state(&ps);
                    }
                }
                // share: a second block table over the same pages
                3 => {
                    if !live.is_empty() {
                        let i = rng.below(live.len());
                        let shared = pool.share_state(&live[i].2);
                        let (d, e, _) = &live[i];
                        live.push((d.clone(), e.clone(), shared));
                    }
                }
                // copy-on-write: rewrite one page of one table; every
                // other table sharing that page must keep its old bytes
                4 => {
                    if !live.is_empty() {
                        let i = rng.below(live.len());
                        let total = live[i].0.len() + live[i].1.len();
                        let pi = rng.below(live[i].2.pages.len());
                        let lo = pi * pe;
                        let hi = ((pi + 1) * pe).min(total);
                        let content: Vec<f32> =
                            (lo..hi).map(|_| rng.val()).collect();
                        let (data, extra, ps) = &mut live[i];
                        let nid = pool.update(ps.pages[pi], &content);
                        ps.pages[pi] = nid;
                        for (j, &v) in content.iter().enumerate() {
                            image_set(data, extra, lo + j, v);
                        }
                    }
                }
                // tiering round trip: demote to disk, promote back
                _ => {
                    if !live.is_empty() {
                        let i = rng.below(live.len());
                        pool.park_cold(std::slice::from_ref(&live[i].2)).unwrap();
                        pool.promote(std::slice::from_ref(&live[i].2)).unwrap();
                    }
                }
            }
            if !live.is_empty() {
                let i = rng.below(live.len());
                let (d, e, ps) = &live[i];
                assert_round_trip(&pool, d, e, ps, &format!("seed {seed} step {step}"));
            }
        }
        for (i, (d, e, ps)) in live.iter().enumerate() {
            assert_round_trip(&pool, d, e, ps, &format!("seed {seed} final state {i}"));
        }
        for (_, _, ps) in &live {
            pool.free_state(ps);
        }
        let s = pool.stats();
        assert_eq!(s.pages_resident, 0, "pool must drain: {s:?}");
        assert_eq!(s.ram_bytes, 0, "freed pages must release RAM: {s:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Sanity check that the oracle verifies what the image helpers assume.
#[test]
fn oracle_image_indexing() {
    let mut d = vec![1.0, 2.0];
    let mut e = vec![3.0];
    assert_eq!(image_get(&d, &e, 2), 3.0);
    image_set(&mut d, &mut e, 2, 5.0);
    assert_eq!(e[0], 5.0);
}

#[test]
fn parked_backend_state_matches_flat_snapshot_oracle() {
    let be = ReferenceBackend::new();
    let prompt = tokenizer::encode(&corpus::continuation_prompt(7, 700));
    let mut target = engine::session::TargetSession::new(
        &be,
        "s",
        specpv::model::bucket_need(prompt.len().min(150), 16, be.consts()),
        OffloadSim::new(Default::default()),
    )
    .unwrap();
    let toks: Vec<u32> = prompt.into_iter().take(150).collect();
    target.prefill(&toks, None, &KvCtx::disabled()).unwrap();

    let snap = target.export().unwrap();
    // odd page size vs the image length exercises the partial tail page
    let pool = KvPool::with_opts(0, 1 << 10, None, KvQuant::None);
    let ps = target.park(&pool).unwrap();
    assert_eq!(ps.image_len() * 4, snap.bytes(), "page ABI and slab ABI disagree");

    // the parked image is bit-for-bit the exported snapshot
    let (data, extra) = pool.read_image(&ps).unwrap();
    assert_eq!(data.len(), snap.data.len());
    assert_eq!(extra.len(), snap.extra.len());
    assert!(
        data.iter().zip(&snap.data).all(|(a, b)| a.to_bits() == b.to_bits()),
        "parked data diverged from export_state"
    );
    assert!(
        extra.iter().zip(&snap.extra).all(|(a, b)| a.to_bits() == b.to_bits()),
        "parked extra rows diverged from export_state"
    );

    // and unparking rebuilds a state whose re-export is identical
    target.restore_paged(&pool, &ps).unwrap();
    let back = target.export().unwrap();
    assert!(
        back.data.iter().zip(&snap.data).all(|(a, b)| a.to_bits() == b.to_bits()),
        "unpark → export diverged"
    );
    pool.free_state(&ps);
}

#[test]
fn corrupt_spill_files_fault_cleanly_and_requeue() {
    let be = ReferenceBackend::new();
    let dir = tmp_dir("swap_fault");
    let mut cfg = Config {
        backend: BackendKind::Reference,
        engine: EngineKind::Autoregressive,
        ..Config::default()
    };
    // no prefix cache: the preempted session's pages must be unshared so
    // park_cold actually spills them to disk
    cfg.prefix_cache_bytes = 0;
    cfg.kv_swap_dir = dir.to_string_lossy().into_owned();

    let prompt = tokenizer::encode(&corpus::continuation_prompt(3, 150));
    let req = GenRequest::greedy(prompt, 12);
    let solo = engine::generate_with(&cfg, &be, &req).unwrap();
    assert!(solo.tokens.len() >= 4, "prompt decodes too few tokens to swap");

    let est = engine::estimate_state_bytes(&be, &cfg, EngineKind::Autoregressive, &req);
    cfg.kv_budget_bytes = est * 3 / 2; // fits one session, never two
    cfg.max_active = 4;

    let mut coord = Coordinator::new(&be, cfg);
    let low = coord
        .submit_opts(req.clone(), SubmitOpts { priority: 0, ..SubmitOpts::default() })
        .unwrap();
    coord.tick();
    coord.tick();
    let high = coord
        .submit_opts(req.clone(), SubmitOpts { priority: 1, ..SubmitOpts::default() })
        .unwrap();

    let mut faults = Vec::new();
    let mut corrupted = false;
    while !coord.idle() {
        for ev in coord.tick() {
            match ev {
                Event::SwappedOut { id } => {
                    assert_eq!(id, low);
                    // clobber every spill page the demotion just wrote;
                    // spills live under per-boot epoch subdirectories
                    // (swap.rs), so walk recursively for `*.kvp` files
                    let n = clobber_spill_pages(&dir);
                    assert!(n > 0, "preemption spilled no pages to {dir:?}");
                    corrupted = true;
                }
                Event::SwapFault { id } => faults.push(id),
                _ => {}
            }
        }
    }
    assert!(corrupted, "low-priority session was never preempted");
    assert_eq!(faults, vec![low], "corrupt spill files must surface as a fault");
    assert_eq!(coord.registry.swap_faults, 1);

    // the faulted request was re-queued and regenerated from scratch —
    // deterministic seeding makes the recovered output identical
    for id in [low, high] {
        let tr = coord.get(id).unwrap();
        let r = tr.result.as_ref().expect("both requests must complete");
        assert_eq!(r.tokens, solo.tokens, "request {id} diverged after the fault");
    }
    let stats = coord.kv_stats();
    assert_eq!(stats.resident_bytes, 0, "pool must drain when idle");
    assert_eq!(stats.swapped, 0, "no session may stay parked");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Epoch isolation at the pool level (DESIGN.md §17): spill pages
/// written by process incarnation N are garbage-collected — and can
/// never be resolved — by incarnation N+1, whose own spills land in a
/// fresh epoch directory.
#[test]
fn boot_epochs_isolate_pool_incarnations() {
    let root = tmp_dir("pool_epochs");

    // incarnation N: spill one parked state's pages to disk, then
    // "crash" — drop the pool with the state still parked, so the
    // spill files stay behind (freeing or promoting would delete them)
    let data: Vec<f32> = (0..300).map(|i| i as f32).collect();
    {
        let pool = KvPool::with_opts(0, 64, Some(&root), KvQuant::None);
        let ps = pool.park_image(StateKind::Full, "s", 64, &data, &[]);
        pool.park_cold(std::slice::from_ref(&ps)).unwrap();
        assert!(
            walk_spill_pages(&root, false) > 0,
            "park_cold spilled nothing under {root:?}"
        );
    }
    let _ = std::fs::write(
        root.join("epoch-00000001").join("p0").join("page-deadbeefdeadbeef.kvp"),
        b"stale page from incarnation N",
    );
    let before = walk_spill_pages(&root, false);
    assert!(before > 0, "incarnation N left no spill files to isolate");

    // incarnation N+1: constructing a boot-scoped pool bumps the epoch
    // and garbage-collects every stale epoch directory
    specpv::kvstore::swap::force_new_boot(&root);
    let pool2 = KvPool::with_opts(0, 64, Some(&root), KvQuant::None);
    assert!(
        !root.join("epoch-00000001").exists(),
        "incarnation N's epoch directory survived the next boot"
    );
    assert_eq!(
        walk_spill_pages(&root, false),
        0,
        "stale spill pages leaked across the boot epoch"
    );

    // the new incarnation's own spills round-trip in its fresh epoch dir
    let ps2 = pool2.park_image(StateKind::Full, "s", 64, &data, &[]);
    pool2.park_cold(std::slice::from_ref(&ps2)).unwrap();
    assert!(walk_spill_pages(&root, false) > 0, "incarnation N+1 spilled nothing");
    pool2.promote(std::slice::from_ref(&ps2)).unwrap();
    let (back, _) = pool2.read_image(&ps2).unwrap();
    assert_eq!(back, data);
    pool2.free_state(&ps2);
    let _ = std::fs::remove_dir_all(&root);
}
