//! Durability acceptance suite (DESIGN.md §17): cold-restart recovery
//! over the write-ahead request journal and the crash-consistent
//! checkpoint store.
//!
//!   * crash = process-equivalent teardown (the abort hook: no drain,
//!     no outbox flush, no journal mark-clean) mid-stream; a second
//!     server incarnation over the same journal dir recovers every
//!     unfinished session and a reconnecting `generate_retry` client
//!     receives exactly the missing suffix — byte-identical to an
//!     undisturbed run, zero duplicated and zero lost wire lines —
//!     on **both** recovery paths (durable-checkpoint resume and
//!     deterministic regeneration from the journal alone);
//!   * graceful shutdown marks the journal clean: the next boot
//!     replays nothing and reports `recovered: 0`;
//!   * journal replay is idempotent and prefix-closed: scanning the
//!     journal truncated at **every** byte length never fails, folds
//!     exactly the complete-record prefix, and flags at most one torn
//!     record; `Journal::open` truncates the torn tail and appends
//!     land cleanly after it.

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use specpv::config::{Config, EngineKind, JournalFsync};
use specpv::coordinator::Coordinator;
use specpv::engine::scripted::ScriptedFactory;
use specpv::engine::GenRequest;
use specpv::json::Json;
use specpv::serve::journal::{self, Journal};
use specpv::serve::{serve_scripted, serve_scripted_abortable};
use specpv::server::Client;
use specpv::tokenizer;

/// Tokens per scripted step; delivery marks and resume boundaries are
/// line-aligned, so the watermark is always a multiple of this.
const TPS: usize = 2;
/// Per-step pacing: slow enough that the abort deterministically lands
/// mid-generation (the client aborts after [`ABORT_DELTAS`] lines,
/// far before the 20-step run completes), fast enough for CI.
const STEP_MICROS: u64 = 15_000;
const MAX_NEW: usize = 40;
const ABORT_DELTAS: usize = 6;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join(name);
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::create_dir_all(&dir);
    dir
}

fn paced_factory() -> ScriptedFactory {
    ScriptedFactory {
        tokens_per_step: TPS,
        step_micros: STEP_MICROS,
        ..ScriptedFactory::default()
    }
}

/// Drive one request through a bare coordinator to completion — the
/// undisturbed pin every recovery path must match byte for byte.
fn direct_run(factory: ScriptedFactory, prompt: &str, max_new: usize) -> String {
    let mut coord = Coordinator::with_factory(Config::default(), Box::new(factory));
    let req = GenRequest::greedy(tokenizer::encode(prompt), max_new);
    let id = coord.submit(req, Some(EngineKind::SpecPv)).unwrap();
    while !coord.idle() {
        coord.tick();
    }
    let tr = coord.get(id).unwrap();
    tr.result.as_ref().expect("direct run must complete").text()
}

fn num(j: &Json, key: &str) -> i64 {
    j.get(key).and_then(|x| x.as_i64()).unwrap_or_else(|| panic!("{key} missing: {j:?}"))
}

fn journaled_cfg(dir: &PathBuf, checkpoint_every: usize) -> Config {
    Config {
        shards: 1,
        checkpoint_every_steps: checkpoint_every,
        journal_dir: dir.to_string_lossy().into_owned(),
        journal_fsync: JournalFsync::Always,
        ..Config::default()
    }
}

/// Boot a journaled scripted server, stream `prompt` until
/// [`ABORT_DELTAS`] delta lines arrived, flip the crash-equivalent
/// abort, and drain the socket to EOF. Returns `(gid, received_text)` —
/// the received text is every fully flushed line, which is exactly what
/// the journal's delivered watermark covers.
fn crash_mid_stream(dir: &PathBuf, checkpoint_every: usize, prompt: &str) -> (u64, String) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = journaled_cfg(dir, checkpoint_every);
    let abort = Arc::new(AtomicBool::new(false));
    let server = {
        let abort = Arc::clone(&abort);
        let factory = paced_factory();
        thread::spawn(move || serve_scripted_abortable(listener, cfg, factory, Some(abort)))
    };
    let mut cl = Client::connect(&addr).unwrap();
    cl.send(
        Json::obj()
            .set("op", "generate")
            .set("prompt", prompt)
            .set("max_new", MAX_NEW)
            .set("engine", "spec_pv")
            .set("stream", true),
    )
    .unwrap();
    let mut gid = None;
    let mut recv_text = String::new();
    let mut deltas = 0usize;
    loop {
        let j = match cl.recv() {
            Ok(j) => j,
            // the abort dropped the connection; every fully flushed
            // line was already consumed, a torn tail line fails parse
            Err(_) => break,
        };
        if gid.is_none() {
            gid = j.get("id").and_then(|x| x.as_i64()).map(|v| v as u64);
        }
        assert_ne!(
            j.get("done").and_then(|x| x.as_bool()),
            Some(true),
            "generation completed before the abort — pacing too fast: {j:?}"
        );
        if let Some(d) = j.get("delta").and_then(|x| x.as_str()) {
            recv_text.push_str(d);
            deltas += 1;
            if deltas == ABORT_DELTAS {
                abort.store(true, Ordering::SeqCst);
            }
        }
    }
    assert!(deltas >= ABORT_DELTAS, "only {deltas} deltas before the connection died");
    server.join().unwrap().unwrap();
    (gid.expect("no ack line with the request id arrived"), recv_text)
}

/// Restart over the same journal dir, reattach with `generate_retry`,
/// and return `(header, resumed_text, final_line, metrics)`.
fn recover_and_resume(dir: &PathBuf, checkpoint_every: usize, gid: u64) -> (Json, String, Json, Json) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = journaled_cfg(dir, checkpoint_every);
    let server =
        thread::spawn(move || serve_scripted(listener, cfg, paced_factory()));
    let mut cl = Client::connect(&addr).unwrap();
    let (header, steps, fin) = cl.resume_stream(gid).unwrap();
    let resumed: String =
        steps.iter().filter_map(|j| j.get("delta").and_then(|x| x.as_str())).collect();
    let m = cl.admin("metrics").unwrap();
    cl.shutdown().unwrap();
    server.join().unwrap().unwrap();
    (header, resumed, fin, m)
}

fn assert_recovered_byte_identical(
    want: &str,
    recv_text: &str,
    header: &Json,
    resumed: &str,
    fin: &Json,
    gid: u64,
) {
    assert_eq!(header.get("ok").and_then(|x| x.as_bool()), Some(true), "{header:?}");
    assert_eq!(header.get("retry").and_then(|x| x.as_bool()), Some(true), "{header:?}");
    assert_eq!(header.get("id").and_then(|x| x.as_i64()), Some(gid as i64));
    assert_eq!(fin.get("ok").and_then(|x| x.as_bool()), Some(true), "{fin:?}");
    assert_eq!(fin.get("tokens").and_then(|x| x.as_usize()), Some(MAX_NEW), "{fin:?}");
    assert_eq!(fin.get("text").and_then(|x| x.as_str()), Some(want), "{fin:?}");
    // zero lost, zero duplicated wire lines across the crash: what the
    // first incarnation flushed plus what the restart replayed is the
    // whole generation, byte for byte
    assert_eq!(
        format!("{recv_text}{resumed}"),
        want,
        "received {} + resumed {} bytes do not reassemble the pin",
        recv_text.len(),
        resumed.len()
    );
    assert!(!recv_text.is_empty(), "crash landed before any delivery");
    assert!(!resumed.is_empty(), "crash landed after the final line");
}

/// Crash mid-stream with periodic durable checkpoints on: the restart
/// resumes from the checkpoint store and the reconnecting client gets
/// exactly the missing suffix.
#[test]
fn cold_restart_checkpoint_resume_byte_identical() {
    let dir = tmp_dir("durability_ckpt");
    let want = direct_run(paced_factory(), "durable pin alpha", MAX_NEW);
    let (gid, recv_text) = crash_mid_stream(&dir, 2, "durable pin alpha");
    let (header, resumed, fin, m) = recover_and_resume(&dir, 2, gid);
    assert_recovered_byte_identical(&want, &recv_text, &header, &resumed, &fin, gid);

    assert_eq!(num(&m, "recovered_sessions"), 1, "{m:?}");
    assert!(num(&m, "journal_replayed") >= 2, "accept + progress records: {m:?}");
    assert_eq!(num(&m, "journal_torn_records"), 0, "{m:?}");
    assert_eq!(num(&m, "checkpoint_resumes"), 1, "restart must use the durable checkpoint: {m:?}");
    assert_eq!(num(&m, "failover_checkpoint"), 1, "{m:?}");
    assert_eq!(num(&m, "failover_regen"), 0, "{m:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Crash mid-stream with checkpointing off: the restart regenerates the
/// session deterministically from the journaled request alone,
/// suppressing everything below the delivered watermark.
#[test]
fn cold_restart_regenerates_from_journal_byte_identical() {
    let dir = tmp_dir("durability_regen");
    let want = direct_run(paced_factory(), "durable pin beta", MAX_NEW);
    let (gid, recv_text) = crash_mid_stream(&dir, 0, "durable pin beta");
    let (header, resumed, fin, m) = recover_and_resume(&dir, 0, gid);
    assert_recovered_byte_identical(&want, &recv_text, &header, &resumed, &fin, gid);

    assert_eq!(num(&m, "recovered_sessions"), 1, "{m:?}");
    assert!(num(&m, "journal_replayed") >= 2, "{m:?}");
    assert_eq!(num(&m, "journal_torn_records"), 0, "{m:?}");
    assert_eq!(num(&m, "checkpoint_resumes"), 0, "no checkpoint store to resume from: {m:?}");
    assert_eq!(num(&m, "failover_checkpoint"), 0, "{m:?}");
    assert_eq!(num(&m, "failover_regen"), 1, "{m:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A request id that was never journaled (or already delivered) is a
/// clean structured error, not a hang.
#[test]
fn generate_retry_unknown_id_errors_cleanly() {
    let dir = tmp_dir("durability_unknown");
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = journaled_cfg(&dir, 0);
    let server = thread::spawn(move || serve_scripted(listener, cfg, paced_factory()));
    let mut cl = Client::connect(&addr).unwrap();
    let (header, steps, fin) = cl.resume_stream(9_999).unwrap();
    assert_eq!(header.get("ok").and_then(|x| x.as_bool()), Some(false), "{header:?}");
    assert!(steps.is_empty());
    assert_eq!(fin.get("ok").and_then(|x| x.as_bool()), Some(false));
    cl.shutdown().unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Graceful shutdown flushes every terminal line, marks the journal
/// clean and clears the checkpoint store — the next boot replays
/// nothing and serves normally.
#[test]
fn clean_shutdown_recovers_nothing() {
    let dir = tmp_dir("durability_clean");
    let want = direct_run(paced_factory(), "durable pin gamma", MAX_NEW);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = journaled_cfg(&dir, 2);
    let server = {
        let cfg = cfg.clone();
        thread::spawn(move || serve_scripted(listener, cfg, paced_factory()))
    };
    let mut cl = Client::connect(&addr).unwrap();
    let (_steps, fin) = cl.generate_stream("durable pin gamma", MAX_NEW, "spec_pv").unwrap();
    assert_eq!(fin.get("text").and_then(|x| x.as_str()), Some(want.as_str()));
    cl.shutdown().unwrap();
    server.join().unwrap().unwrap();

    // second boot over the same journal dir: nothing to recover
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = thread::spawn(move || serve_scripted(listener, cfg, paced_factory()));
    let mut cl = Client::connect(&addr).unwrap();
    let m = cl.admin("metrics").unwrap();
    assert_eq!(num(&m, "recovered_sessions"), 0, "{m:?}");
    assert_eq!(num(&m, "journal_replayed"), 0, "{m:?}");
    assert_eq!(num(&m, "journal_torn_records"), 0, "{m:?}");
    // and the clean restart still serves
    let r = cl.generate("durable pin gamma", MAX_NEW, "spec_pv").unwrap();
    assert_eq!(r.get("text").and_then(|x| x.as_str()), Some(want.as_str()), "{r:?}");
    cl.shutdown().unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A representative journal: two requests accepted, interleaved
/// progress, one finished. Written through the real `Journal` so the
/// bytes exercise the actual framing + header path.
fn sample_records() -> Vec<Json> {
    let r0 = GenRequest::greedy(vec![10, 11, 12], 8);
    let r1 = GenRequest::greedy(vec![20, 21], 6);
    vec![
        journal::accept_record(0, &r0, Some(EngineKind::SpecPv), false, true, None, 0),
        journal::progress_record(0, 2),
        journal::accept_record(1, &r1, None, true, true, Some(1.5), 3),
        journal::progress_record(1, 2),
        journal::progress_record(0, 4),
        journal::done_record(0),
        journal::progress_record(1, 4),
    ]
}

fn journal_bytes(dir: &PathBuf, records: &[Json]) -> Vec<u8> {
    {
        let (mut jnl, replay) = Journal::open(dir, JournalFsync::Never).unwrap();
        assert_eq!(replay.records, 0, "fresh dir must start empty");
        for r in records {
            jnl.append(r).unwrap();
        }
    }
    std::fs::read(dir.join(journal::JOURNAL_FILE)).unwrap()
}

/// Prefix closure + torn-tail tolerance at **every** byte length: a
/// journal truncated anywhere folds exactly its complete-record prefix,
/// flags at most one torn record, and never errors.
#[test]
fn journal_scan_is_prefix_closed_at_every_truncation() {
    let dir = tmp_dir("durability_scan_prop");
    let records = sample_records();
    let bytes = journal_bytes(&dir, &records);
    // record end offsets within the file (header + frame lengths)
    let mut ends = vec![8u64];
    for r in &records {
        ends.push(ends.last().unwrap() + journal::frame(r).len() as u64);
    }
    assert_eq!(*ends.last().unwrap(), bytes.len() as u64, "frame math disagrees with the file");

    for cut in 0..=bytes.len() {
        let rp = journal::scan_bytes(&bytes[..cut]);
        if cut == 0 {
            assert_eq!(rp.records, 0);
            assert_eq!(rp.torn, 0, "an empty file is fresh, not torn");
            continue;
        }
        if (cut as u64) < 8 {
            assert_eq!(rp.records, 0);
            assert_eq!(rp.torn, 1, "a torn header is flagged (cut={cut})");
            continue;
        }
        // complete records that fit in this prefix
        let k = ends.iter().filter(|&&e| e <= cut as u64).count() - 1;
        let mut want = journal::Replay::default();
        for r in &records[..k] {
            want.fold(r);
        }
        assert_eq!(rp.records, k as u64, "cut={cut}");
        assert_eq!(rp.requests, want.requests, "cut={cut}");
        assert_eq!(rp.done, want.done, "cut={cut}");
        assert_eq!(rp.next_gid, want.next_gid, "cut={cut}");
        assert_eq!(rp.valid_len, ends[k], "cut={cut}");
        let boundary = ends.contains(&(cut as u64));
        assert_eq!(rp.torn, u64::from(!boundary), "cut={cut}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Replay is idempotent: folding the whole journal a second time over
/// the already-folded state changes nothing.
#[test]
fn journal_replay_is_idempotent() {
    let records = sample_records();
    let mut once = journal::Replay::default();
    for r in &records {
        once.fold(r);
    }
    let mut twice = journal::Replay::default();
    for r in records.iter().chain(records.iter()) {
        twice.fold(r);
    }
    assert_eq!(once.requests, twice.requests);
    assert_eq!(once.done, twice.done);
    assert_eq!(once.next_gid, twice.next_gid);
    // the folded state is sane: gid 0 finished, gid 1 is outstanding at
    // its max-merged watermark
    assert!(once.done.contains(&0));
    assert_eq!(once.requests.len(), 1);
    assert_eq!(once.requests[&1].delivered, 4);
    assert_eq!(once.next_gid, 2);
}

/// `Journal::open` on a file torn at every length inside the final
/// record: the tail is truncated (not fatal), the fold matches the
/// complete-record prefix, and a subsequent append lands cleanly.
#[test]
fn journal_open_truncates_torn_tail_and_appends_after_it() {
    let build = tmp_dir("durability_open_src");
    let records = sample_records();
    let bytes = journal_bytes(&build, &records);
    let mut ends = vec![8u64];
    for r in &records {
        ends.push(ends.last().unwrap() + journal::frame(r).len() as u64);
    }
    let last_clean = ends[ends.len() - 2];

    for cut in last_clean..(bytes.len() as u64) {
        let dir = tmp_dir("durability_open_case");
        std::fs::write(dir.join(journal::JOURNAL_FILE), &bytes[..cut as usize]).unwrap();
        let (mut jnl, replay) = Journal::open(&dir, JournalFsync::Never).unwrap();
        assert_eq!(replay.records, records.len() as u64 - 1, "cut={cut}");
        assert_eq!(replay.torn, u64::from(cut != last_clean), "cut={cut}");
        assert_eq!(replay.valid_len, last_clean, "cut={cut}");
        assert_eq!(
            std::fs::metadata(dir.join(journal::JOURNAL_FILE)).unwrap().len(),
            last_clean,
            "open must truncate the torn tail (cut={cut})"
        );
        // appends after the truncation are clean and replayable
        jnl.append(&journal::done_record(1)).unwrap();
        drop(jnl);
        let (_, again) = Journal::open(&dir, JournalFsync::Never).unwrap();
        assert_eq!(again.records, records.len() as u64, "cut={cut}");
        assert_eq!(again.torn, 0, "cut={cut}");
        assert!(again.requests.is_empty(), "both gids are finished now (cut={cut})");
        assert!(again.done.contains(&1), "cut={cut}");
        let _ = std::fs::remove_dir_all(&dir);
    }
    let _ = std::fs::remove_dir_all(&build);
}
