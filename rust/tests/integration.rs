//! Integration tests over the **pjrt backend** + AOT artifacts.
//!
//! These need `make artifacts` to have run; they skip (pass trivially)
//! when `artifacts/manifest.json` is absent so `cargo test` works in a
//! fresh checkout. The artifact-free equivalents of the engine-level
//! guarantees run unconditionally against the reference backend in
//! `rust/tests/reference_e2e.rs`. The heavyweight guarantees here:
//!   * AR decoding == chunk-prefill continuation (runtime coherence)
//!   * spec_full output == AR output  (LOSSLESSNESS of tree verification)
//!   * spec_pv with an oversized budget ≈ spec_full
//!   * every engine runs and reports sane telemetry
//!   * the coordinator + TCP server round-trip

use std::path::{Path, PathBuf};

use specpv::backend::pjrt::PjrtBackend;
use specpv::backend::Backend;
use specpv::config::{Config, EngineKind};
use specpv::engine::{self, GenRequest};
use specpv::runtime::Runtime;
use specpv::{corpus, tokenizer};

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

/// Per-test backend (the PJRT wrapper holds raw pointers and is not
/// Sync; tests run with --test-threads=1 via the Makefile, but each test
/// owning its backend keeps them correct under any harness settings).
fn backend() -> Option<PjrtBackend> {
    let dir = artifacts()?;
    Some(PjrtBackend::new(&dir).expect("pjrt backend init"))
}

fn base_cfg() -> Config {
    Config {
        artifacts_dir: artifacts().unwrap_or_else(|| PathBuf::from("artifacts")),
        ..Config::default()
    }
}

fn gen(
    be: &dyn Backend,
    kind: EngineKind,
    prompt: &str,
    max_new: usize,
) -> specpv::engine::GenResult {
    let mut cfg = base_cfg();
    cfg.engine = kind;
    engine::generate_with(&cfg, be, &GenRequest::greedy(tokenizer::encode(prompt), max_new))
        .expect("generation")
}

#[test]
fn ar_generates_text() {
    let Some(be) = backend() else { return };
    let be: &dyn Backend = &be;
    let prompt = corpus::continuation_prompt(5, 600);
    let r = gen(be, EngineKind::Autoregressive, &prompt, 32);
    assert_eq!(r.tokens.len(), 32);
    assert!(r.stats.throughput() > 0.0);
    // trained char-LM must produce mostly printable ASCII words
    let text = r.text();
    let printable = text
        .chars()
        .filter(|c| c.is_ascii_graphic() || *c == ' ' || *c == '\n')
        .count();
    assert!(printable * 10 >= text.len() * 9, "garbage output: {text:?}");
}

#[test]
fn spec_full_is_lossless_vs_ar() {
    let Some(be) = backend() else { return };
    let be: &dyn Backend = &be;
    let prompt = corpus::continuation_prompt(7, 700);
    let a = gen(be, EngineKind::Autoregressive, &prompt, 48);
    let b = gen(be, EngineKind::SpecFull, &prompt, 48);
    assert_eq!(
        a.tokens, b.tokens,
        "speculative full verification must match AR greedy decoding\nAR:  {:?}\nSF:  {:?}",
        a.text(), b.text()
    );
    assert!(b.stats.accept_len() >= 0.0);
}

#[test]
fn spec_pv_runs_all_modes() {
    let Some(be) = backend() else { return };
    let be: &dyn Backend = &be;
    // long enough prompt that the partial cache engages (budget 256 →
    // core ≈ 352 tokens)
    let prompt = corpus::continuation_prompt(9, 900);
    let mut cfg = base_cfg();
    cfg.engine = EngineKind::SpecPv;
    cfg.specpv.retrieval_budget = 256;
    let r = engine::generate_with(
        &cfg,
        be,
        &GenRequest::greedy(tokenizer::encode(&prompt), 64),
    )
    .unwrap();
    assert_eq!(r.tokens.len(), 64);
    assert!(r.stats.refresh_steps >= 1, "no refresh happened: {:?}", r.stats);
    assert!(r.stats.partial_steps >= 1, "no partial steps: {:?}", r.stats);
}

#[test]
fn spec_pv_matches_full_on_short_context() {
    let Some(be) = backend() else { return };
    let be: &dyn Backend = &be;
    // prompt shorter than the partial core → SpecPV stays in Full mode
    // and must be exactly lossless
    let prompt = corpus::continuation_prompt(11, 300);
    let mut cfg = base_cfg();
    cfg.engine = EngineKind::SpecPv;
    cfg.specpv.retrieval_budget = 512;
    let pv = engine::generate_with(
        &cfg,
        be,
        &GenRequest::greedy(tokenizer::encode(&prompt), 40),
    )
    .unwrap();
    let full = gen(be, EngineKind::SpecFull, &prompt, 40);
    assert_eq!(pv.tokens, full.tokens);
    assert_eq!(pv.stats.partial_steps, 0);
}

#[test]
fn triforce_and_tokenswift_run() {
    let Some(be) = backend() else { return };
    let be: &dyn Backend = &be;
    let prompt = corpus::continuation_prompt(13, 700);
    for kind in [EngineKind::TriForce, EngineKind::TokenSwift] {
        let r = gen(be, kind, &prompt, 32);
        assert_eq!(r.tokens.len(), 32, "{kind:?}");
        // both verify on the full cache → lossless vs AR
        let a = gen(be, EngineKind::Autoregressive, &prompt, 32);
        assert_eq!(r.tokens, a.tokens, "{kind:?} diverged from AR");
    }
}

#[test]
fn offload_sim_adds_cost_to_full_but_not_partial() {
    let Some(be) = backend() else { return };
    let be: &dyn Backend = &be;
    let prompt = corpus::continuation_prompt(15, 900);
    let mut cfg = base_cfg();
    cfg.offload.enabled = true;
    cfg.engine = EngineKind::SpecFull;
    let full = engine::generate_with(
        &cfg,
        be,
        &GenRequest::greedy(tokenizer::encode(&prompt), 32),
    )
    .unwrap();
    assert!(full.stats.offload_secs > 0.0);
    cfg.engine = EngineKind::SpecPv;
    cfg.specpv.retrieval_budget = 256;
    let pv = engine::generate_with(
        &cfg,
        be,
        &GenRequest::greedy(tokenizer::encode(&prompt), 32),
    )
    .unwrap();
    // partial steps don't touch the offloaded cache → less simulated PCIe
    assert!(pv.stats.offload_secs < full.stats.offload_secs);
}

#[test]
fn coordinator_queue_and_metrics() {
    let Some(be) = backend() else { return };
    let mut coord = specpv::coordinator::Coordinator::new(&be, base_cfg());
    let p = corpus::continuation_prompt(21, 400);
    let id1 = coord
        .submit(GenRequest::greedy(tokenizer::encode(&p), 16), None)
        .unwrap();
    let id2 = coord
        .submit(
            GenRequest::greedy(tokenizer::encode(&p), 16),
            Some(EngineKind::Autoregressive),
        )
        .unwrap();
    coord.run_all();
    for id in [id1, id2] {
        let tr = coord.get(id).unwrap();
        assert_eq!(tr.state, specpv::coordinator::RequestState::Done);
        assert_eq!(tr.result.as_ref().unwrap().tokens.len(), 16);
    }
    assert_eq!(coord.registry.completed, 2);
    // per-backend counters flow into the registry summary
    assert!(coord.registry.executions > 0);
    assert!(coord.registry.summary().contains("backend=pjrt"));
}

#[test]
fn coordinator_rejects_oversized() {
    let Some(be) = backend() else { return };
    let mut coord = specpv::coordinator::Coordinator::new(&be, base_cfg());
    let huge = vec![65u32; 100_000];
    assert!(coord.submit(GenRequest::greedy(huge, 16), None).is_err());
    assert!(coord
        .submit(GenRequest::greedy(vec![65; 10], 1 << 20), None)
        .is_err());
}

#[test]
fn server_roundtrip() {
    let Some(dir) = artifacts() else { return };
    let mut cfg = base_cfg();
    cfg.server_addr = "127.0.0.1:7913".into();
    std::thread::scope(|s| {
        // the server thread owns its backend (PJRT handles are !Send)
        let cfg2 = cfg.clone();
        let dir2 = dir.clone();
        let h = s.spawn(move || {
            let be = PjrtBackend::new(&dir2).expect("server backend");
            let _ = specpv::server::serve(&be, cfg2);
        });
        std::thread::sleep(std::time::Duration::from_millis(300));
        let mut client = specpv::server::Client::connect("127.0.0.1:7913").unwrap();
        let pong = client
            .call(specpv::json::Json::obj().set("op", "ping"))
            .unwrap();
        assert_eq!(pong.get("ok").and_then(|x| x.as_bool()), Some(true));
        let r = client.generate("Once upon a time, ", 16, "spec_full").unwrap();
        assert_eq!(r.get("ok").and_then(|x| x.as_bool()), Some(true), "{r:?}");
        assert!(r.get("text").and_then(|x| x.as_str()).is_some());
        client.shutdown().unwrap();
        h.join().unwrap();
    });
}

#[test]
fn runtime_rejects_bad_invocations() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).expect("runtime init");
    // unknown executable
    assert!(rt.invoke("nope_exec", &[]).is_err());
    // wrong arg count
    let name = "read_tiny_b512";
    assert!(rt.invoke(name, &[]).is_err());
}

#[test]
fn failure_injection_truncated_artifact() {
    let Some(dir) = artifacts() else { return };
    // copy artifacts manifest into a temp dir with a truncated hlo file
    let tmp = std::env::temp_dir().join("specpv_bad_artifacts");
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::copy(dir.join("manifest.json"), tmp.join("manifest.json")).unwrap();
    std::fs::copy(dir.join("weights_s.bin"), tmp.join("weights_s.bin")).unwrap();
    std::fs::write(tmp.join("verify_s_b1024_t1.hlo.txt"), "HloModule garbage{{{").unwrap();
    let rt = Runtime::new(&tmp).unwrap(); // lazy compile → ok to build
    let err = rt.invoke("verify_s_b1024_t1", &[]);
    assert!(err.is_err());
    let missing = rt.invoke("verify_s_b8192_t1", &[]);
    assert!(missing.is_err()); // file absent in the temp dir
}
