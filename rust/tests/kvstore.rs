//! KV state manager acceptance suite (reference backend, no artifacts):
//!
//!   * snapshot fidelity: export → import → continue is byte-identical
//!     to an unsuspended run for all five engines (suspend/resume after
//!     every decode round, so SpecPV swaps in every mode);
//!   * prefix cache: a hit produces byte-identical output to a cold
//!     prefill, a prompt extending a cached prefix restores the longest
//!     boundary, and the paired EAGLE draft state rides along;
//!   * admission: `estimate_state_bytes` equals the live session's
//!     `state_bytes()` for every engine (the pool charges what runs);
//!   * swapping: a forced swap-out/swap-in mid-generation under a tight
//!     `kv_budget_bytes` completes with identical tokens, and the pool
//!     drains back to zero.

use specpv::backend::reference::ReferenceBackend;
use specpv::backend::Backend;
use specpv::config::{BackendKind, Config, EngineKind, SpecPvConfig};
use specpv::coordinator::{Coordinator, Event, SubmitOpts};
use specpv::corpus;
use specpv::engine::{self, GenRequest};
use specpv::kvstore::{KvCtx, KvStore};
use specpv::tokenizer;

fn base_cfg() -> Config {
    Config {
        backend: BackendKind::Reference,
        // small retrieval budget so the SpecPV mode machine leaves Full
        // mode on test-sized prompts (see reference_e2e.rs)
        specpv: SpecPvConfig { retrieval_budget: 64, ..SpecPvConfig::default() },
        ..Config::default()
    }
}

fn cfg_for(kind: EngineKind) -> Config {
    let mut cfg = base_cfg();
    cfg.engine = kind;
    cfg
}

/// A prompt whose AR continuation runs long enough for the scenario
/// (seeded weights may emit EOS early for some prompts).
fn long_running_prompt(be: &dyn Backend, bytes: usize, min_tokens: usize) -> Vec<u32> {
    for seed in 0..16u64 {
        let prompt = tokenizer::encode(&corpus::continuation_prompt(seed, bytes));
        let r = engine::generate_with(
            &cfg_for(EngineKind::Autoregressive),
            be,
            &GenRequest::greedy(prompt.clone(), min_tokens),
        )
        .unwrap();
        if r.tokens.len() >= min_tokens {
            return prompt;
        }
    }
    panic!("no candidate prompt decoded {min_tokens}+ tokens");
}

const ALL_ENGINES: [EngineKind; 5] = [
    EngineKind::Autoregressive,
    EngineKind::SpecFull,
    EngineKind::SpecPv,
    EngineKind::TriForce,
    EngineKind::TokenSwift,
];

#[test]
fn suspend_resume_is_byte_identical_for_all_engines() {
    let be = ReferenceBackend::new();
    // (160, 24) + 48 new tokens mirrors reference_e2e's SpecPV mode-
    // machine test, so every engine is known to run multiple rounds here
    let prompt = long_running_prompt(&be, 160, 24);
    for kind in ALL_ENGINES {
        let cfg = cfg_for(kind);
        let req = GenRequest::greedy(prompt.clone(), 48);
        let baseline = engine::generate_with(&cfg, &be, &req).unwrap();

        // swap after every round: every engine mode (incl. SpecPV's
        // Full / Refresh / Partial) crosses a suspend boundary
        let mut session =
            engine::build(&cfg).start(&be, &req, &KvCtx::disabled()).unwrap();
        let mut rounds = 0usize;
        while !session.is_finished() {
            session.step().unwrap();
            rounds += 1;
            if !session.is_finished() {
                let snaps = session.suspend().unwrap();
                assert!(
                    !snaps.is_empty(),
                    "{kind:?} suspended to no snapshots"
                );
                session.resume(snaps).unwrap();
            }
        }
        assert!(rounds > 1, "{kind:?} finished before any suspend happened");
        let swapped = session.finish();
        assert_eq!(
            swapped.tokens, baseline.tokens,
            "{kind:?}: suspend/resume changed the output"
        );
    }
}

#[test]
fn prefix_cache_hit_is_byte_identical_to_cold_prefill() {
    let be = ReferenceBackend::new();
    let chunk = be.consts().chunk;
    let prompt = long_running_prompt(&be, 4 * chunk + 40, 4);
    assert!(prompt.len() > 2 * chunk, "prompt must span several chunks");
    // ar (target only) and spec_full (paired draft snapshot) both go
    // through the cache
    for kind in [EngineKind::Autoregressive, EngineKind::SpecFull] {
        let cfg = cfg_for(kind);
        let req = GenRequest::greedy(prompt.clone(), 8);
        let cold = engine::generate_with(&cfg, &be, &req).unwrap();
        let store = KvStore::new(32 << 20);
        let miss = engine::generate_with_store(&cfg, &be, &req, Some(&store)).unwrap();
        let hit = engine::generate_with_store(&cfg, &be, &req, Some(&store)).unwrap();
        assert_eq!(miss.tokens, cold.tokens, "{kind:?}: miss path diverged");
        assert_eq!(hit.tokens, cold.tokens, "{kind:?}: hit path diverged");
        let s = store.stats();
        assert!(s.insertions >= 1, "{kind:?}: nothing cached: {s:?}");
        assert!(s.misses >= 1, "{kind:?}: first run should miss: {s:?}");
        assert!(s.hits >= 1, "{kind:?}: second run should hit: {s:?}");
    }
}

#[test]
fn prompt_extending_a_cached_prefix_restores_the_longest_boundary() {
    let be = ReferenceBackend::new();
    let chunk = be.consts().chunk;
    let base = long_running_prompt(&be, 5 * chunk, 4);
    assert!(base.len() > 4 * chunk + 20);
    // both prompts sized to pick the same full bucket (the prefix-cache
    // geometry key includes it)
    let long: Vec<u32> = base[..4 * chunk + 20].to_vec();
    let short: Vec<u32> = base[..3 * chunk + 9].to_vec();
    let cfg = cfg_for(EngineKind::Autoregressive);
    let store = KvStore::new(32 << 20);

    // prime with the short prompt (inserts its 3-chunk boundary)
    let short_req = GenRequest::greedy(short, 8);
    let cold_short = engine::generate_with(&cfg, &be, &short_req).unwrap();
    let warm_short =
        engine::generate_with_store(&cfg, &be, &short_req, Some(&store)).unwrap();
    assert_eq!(warm_short.tokens, cold_short.tokens);

    // the long prompt extends the cached prefix: restore + tail prefill
    let long_req = GenRequest::greedy(long.clone(), 8);
    let cold_long = engine::generate_with(&cfg, &be, &long_req).unwrap();
    let warm_long =
        engine::generate_with_store(&cfg, &be, &long_req, Some(&store)).unwrap();
    assert_eq!(
        warm_long.tokens, cold_long.tokens,
        "extension restore diverged from cold prefill"
    );
    let after_ext = store.stats();
    assert!(after_ext.hits >= 1, "extension did not hit: {after_ext:?}");
    // the extension run re-exported at its own (longer) boundary…
    assert!(after_ext.insertions >= 2, "no extension insert: {after_ext:?}");
    // …so an identical long prompt now restores the longest boundary
    let again = engine::generate_with_store(&cfg, &be, &long_req, Some(&store)).unwrap();
    assert_eq!(again.tokens, cold_long.tokens);
    assert!(store.stats().hits >= 2);
}

#[test]
fn estimate_matches_live_session_state_bytes() {
    let be = ReferenceBackend::new();
    let prompt = long_running_prompt(&be, 150, 4);
    let req = GenRequest::greedy(prompt, 16);
    for kind in ALL_ENGINES {
        let cfg = cfg_for(kind);
        let est = engine::estimate_state_bytes(&be, &cfg, kind, &req);
        assert!(est > 0, "{kind:?}: zero estimate");
        let session =
            engine::build(&cfg).start(&be, &req, &KvCtx::disabled()).unwrap();
        assert_eq!(
            est,
            session.state_bytes(),
            "{kind:?}: admission estimate drifted from the live session"
        );
    }
}

#[test]
fn forced_swap_under_tight_budget_is_byte_identical() {
    let be = ReferenceBackend::new();
    let prompt = long_running_prompt(&be, 150, 12);
    let req = GenRequest::greedy(prompt, 12);
    let mut cfg = cfg_for(EngineKind::Autoregressive);
    let est = engine::estimate_state_bytes(&be, &cfg, EngineKind::Autoregressive, &req);
    assert!(est > 0);
    // fits one session, never two
    cfg.kv_budget_bytes = est * 3 / 2;
    cfg.max_active = 4;

    let solo = engine::generate_with(&cfg, &be, &req).unwrap();

    let mut coord = Coordinator::new(&be, cfg);
    let low = coord
        .submit_opts(req.clone(), SubmitOpts { priority: 0, ..SubmitOpts::default() })
        .unwrap();
    // let the low-priority request run a couple of rounds first
    coord.tick();
    coord.tick();
    assert_eq!(coord.active_len(), 1);
    let high = coord
        .submit_opts(req.clone(), SubmitOpts { priority: 1, ..SubmitOpts::default() })
        .unwrap();

    let mut swapped_out = Vec::new();
    let mut resumed = Vec::new();
    while !coord.idle() {
        for ev in coord.tick() {
            match ev {
                Event::SwappedOut { id } => swapped_out.push(id),
                Event::Resumed { id } => resumed.push(id),
                _ => {}
            }
        }
    }
    assert_eq!(swapped_out, vec![low], "low-priority session must be preempted");
    assert_eq!(resumed, vec![low], "preempted session must resume");
    assert_eq!(coord.registry.swap_outs, 1);
    assert_eq!(coord.registry.swap_ins, 1);

    for id in [low, high] {
        let tr = coord.get(id).unwrap();
        let r = tr.result.as_ref().expect("result");
        assert_eq!(
            r.tokens, solo.tokens,
            "request {id} diverged after swapping (state restore is not exact)"
        );
    }
    let stats = coord.kv_stats();
    assert_eq!(stats.resident_bytes, 0, "pool must drain when idle");
    assert_eq!(stats.swapped, 0, "swap store must drain when idle");
    assert!(stats.budget_bytes > 0);
}
