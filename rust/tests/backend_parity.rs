//! Parity and determinism pins for the optimized reference backend:
//!
//!   * the fast kernel pipeline (blocked parallel matmuls, scratch arena,
//!     RoPE tables, lazy logits) is **byte-identical** to the naive
//!     scalar oracle (`ReferenceBackend::naive()`) — at the raw-op level
//!     and across whole engine generations;
//!   * the thread count never changes a single byte: a SpecPV session at
//!     1 thread equals the same session at N threads, bit for bit.

use specpv::backend::reference::ReferenceBackend;
use specpv::backend::{Backend, PrefillOp, ReadOp, StateKind, VerifyOp};
use specpv::config::{BackendKind, Config, EngineKind, SpecPvConfig};
use specpv::corpus;
use specpv::engine::{self, GenRequest};
use specpv::tokenizer;
use specpv::tree;

fn base_cfg() -> Config {
    Config {
        backend: BackendKind::Reference,
        // small core so SpecPV leaves Full mode on the test prompts
        specpv: SpecPvConfig { retrieval_budget: 64, ..SpecPvConfig::default() },
        ..Config::default()
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Run a fixed op sequence (prefill chunk → tail read → tree verify →
/// window read) and return every downloaded byte.
fn op_trace(be: &dyn Backend) -> Vec<u32> {
    let consts = be.consts().clone();
    let c = consts.chunk;
    let bucket = 288;
    let st = be.alloc_state(StateKind::Full, "s", bucket).unwrap();
    let toks: Vec<i32> = (0..c).map(|i| 65 + (i % 26) as i32).collect();
    let pos: Vec<i32> = (0..c as i32).collect();
    let mask = tree::chain_mask(c, c);
    let op = PrefillOp { size: "s", bucket, tokens: &toks, pos: &pos, mask: &mask, kv_len: 0 };
    let st = be.prefill(&op, st).unwrap();
    let mut out = bits(
        &be.read_logits(&ReadOp::LastRow { size: "s", bucket, idx: c - 1 }, &st).unwrap(),
    );
    let t = consts.tree_t;
    let ttoks: Vec<i32> = (0..t as i32).map(|i| 70 + i).collect();
    let tpos: Vec<i32> = (0..t).map(|i| (c + i) as i32).collect();
    let tmask = tree::chain_mask(t, t);
    let zero = [0i32; 8];
    let vop = VerifyOp {
        size: "s",
        bucket,
        t,
        tokens: &ttoks,
        pos: &tpos,
        mask: &tmask,
        kv_len: c,
        prev_idx: &zero,
        n_prev: 0,
    };
    let st = be.verify_full(&vop, st).unwrap();
    out.extend(bits(
        &be.read_logits(&ReadOp::FullWindow { size: "s", bucket, start: 0 }, &st).unwrap(),
    ));
    out
}

#[test]
fn fast_backend_matches_naive_oracle_at_op_level() {
    let fast = op_trace(&ReferenceBackend::new());
    let naive = op_trace(&ReferenceBackend::naive());
    assert_eq!(fast.len(), naive.len());
    assert_eq!(fast, naive, "fast kernels diverged from the scalar oracle");
}

#[test]
fn generation_is_identical_across_kernel_modes() {
    let fast = ReferenceBackend::new();
    let naive = ReferenceBackend::naive();
    let prompt = corpus::continuation_prompt(7, 160);
    let req = GenRequest::greedy(tokenizer::encode(&prompt), 32);
    for kind in [EngineKind::SpecFull, EngineKind::SpecPv, EngineKind::TriForce] {
        let mut cfg = base_cfg();
        cfg.engine = kind;
        let a = engine::generate_with(&cfg, &fast, &req).unwrap();
        let b = engine::generate_with(&cfg, &naive, &req).unwrap();
        assert_eq!(a.tokens, b.tokens, "{kind:?}: kernel mode changed the output");
    }
}

#[test]
fn generation_is_identical_across_thread_counts() {
    let one = ReferenceBackend::with_threads(1);
    let four = ReferenceBackend::with_threads(4);
    assert_eq!(op_trace(&one), op_trace(&four), "thread count changed raw op bytes");
    let prompt = corpus::continuation_prompt(9, 170);
    let req = GenRequest::greedy(tokenizer::encode(&prompt), 40);
    for kind in [EngineKind::Autoregressive, EngineKind::SpecPv] {
        let mut cfg = base_cfg();
        cfg.engine = kind;
        let a = engine::generate_with(&cfg, &one, &req).unwrap();
        let b = engine::generate_with(&cfg, &four, &req).unwrap();
        assert_eq!(a.tokens, b.tokens, "{kind:?}: thread count changed the output");
    }
}
