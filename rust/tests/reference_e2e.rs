//! End-to-end engine tests on the **pure-Rust reference backend** — no
//! artifacts required, so these run everywhere (CI included) and never
//! skip. They pin the guarantees the artifact-gated suite can only check
//! when `make artifacts` has run:
//!
//!   * all five engines produce tokens end-to-end;
//!   * full-verification engines (spec_full, triforce, tokenswift) are
//!     lossless vs AR greedy decoding;
//!   * SpecPV exercises the whole Full → Refresh → Partial mode machine
//!     (≥ 1 Refresh) on a long prompt;
//!   * partial verification over a full-coverage gathered core produces
//!     the same logits as full verification (the §3.2 invariant);
//!   * `generate_with` is byte-deterministic across runs and backend
//!     instances (seeded weights + fixed-order float loops);
//!   * the coordinator and the TCP server serve the reference backend and
//!     report per-backend execution counters.

use specpv::backend::reference::ReferenceBackend;
use specpv::backend::Backend;
use specpv::config::{BackendKind, Config, EngineKind, SpecPvConfig};
use specpv::corpus;
use specpv::engine::session::{PartialSession, TargetSession};
use specpv::engine::{self, GenRequest, GenResult};
use specpv::offload::OffloadSim;
use specpv::retrieval::plan_gather;
use specpv::tokenizer::{self, is_eos};
use specpv::tree::Tree;

fn base_cfg() -> Config {
    Config {
        backend: BackendKind::Reference,
        // keep the partial core smaller than the test prompts so the
        // SpecPV mode machine leaves Full mode (reference block = 16 →
        // core = 64 + 3·16 = 112 tokens)
        specpv: SpecPvConfig { retrieval_budget: 64, ..SpecPvConfig::default() },
        ..Config::default()
    }
}

fn gen(be: &dyn Backend, kind: EngineKind, prompt: &str, max_new: usize) -> GenResult {
    let mut cfg = base_cfg();
    cfg.engine = kind;
    engine::generate_with(&cfg, be, &GenRequest::greedy(tokenizer::encode(prompt), max_new))
        .expect("generation")
}

/// A prompt whose AR continuation runs long enough to exercise multi-step
/// decoding (the seeded random weights may emit EOS early for some
/// prompts; weights and prompts are deterministic, so the scan is too).
fn long_running_prompt(be: &dyn Backend, bytes: usize, min_tokens: usize) -> String {
    for seed in 0..16u64 {
        let prompt = corpus::continuation_prompt(seed, bytes);
        let r = gen(be, EngineKind::Autoregressive, &prompt, min_tokens);
        if r.tokens.len() >= min_tokens {
            return prompt;
        }
    }
    panic!("no candidate prompt decoded {min_tokens}+ tokens");
}

/// Losslessness modulo the shared EOS edge: compare the streams up to and
/// including the first EOS either side emitted.
fn assert_lossless(kind: EngineKind, a: &[u32], b: &[u32]) {
    let cut = |xs: &[u32]| {
        xs.iter()
            .position(|&t| is_eos(t))
            .map(|i| i + 1)
            .unwrap_or(xs.len())
    };
    let n = cut(a).min(cut(b));
    assert!(n > 0, "{kind:?}: empty outputs");
    assert_eq!(&a[..n], &b[..n], "{kind:?} diverged from AR greedy decoding");
}

#[test]
fn all_five_engines_produce_tokens() {
    let be = ReferenceBackend::new();
    let prompt = long_running_prompt(&be, 150, 8);
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::SpecFull,
        EngineKind::SpecPv,
        EngineKind::TriForce,
        EngineKind::TokenSwift,
    ] {
        let r = gen(&be, kind, &prompt, 32);
        assert!(!r.tokens.is_empty(), "{kind:?} produced nothing");
        assert!(r.tokens.len() <= 32, "{kind:?} overshot max_new");
        assert!(r.stats.verify_steps > 0, "{kind:?} ran no verify steps");
        assert_eq!(r.stats.new_tokens, r.tokens.len());
    }
}

#[test]
fn full_verification_engines_are_lossless_vs_ar() {
    let be = ReferenceBackend::new();
    let prompt = long_running_prompt(&be, 150, 24);
    let ar = gen(&be, EngineKind::Autoregressive, &prompt, 40);
    for kind in [EngineKind::SpecFull, EngineKind::TriForce, EngineKind::TokenSwift] {
        let r = gen(&be, kind, &prompt, 40);
        assert_lossless(kind, &ar.tokens, &r.tokens);
    }
}

#[test]
fn spec_pv_exercises_refresh_and_partial_modes() {
    let be = ReferenceBackend::new();
    // prompt longer than the partial core (112 tokens at budget 64) so
    // the session must Refresh (gather a core) and then verify partially
    let prompt = long_running_prompt(&be, 160, 24);
    let r = gen(&be, EngineKind::SpecPv, &prompt, 48);
    assert!(!r.tokens.is_empty());
    assert!(
        r.stats.refresh_steps >= 1,
        "no Refresh step ran: {:?}",
        r.stats
    );
    assert!(
        r.stats.partial_steps >= 1,
        "no partial-verification step ran: {:?}",
        r.stats
    );
    assert_eq!(
        r.stats.verify_steps,
        r.stats.full_steps + r.stats.partial_steps + r.stats.refresh_steps,
        "mode counts must partition the verify steps"
    );
}

/// The §3.2 invariant behind SpecPV: when the gathered core covers the
/// *whole* committed cache, partial verification sees exactly the rows
/// full verification sees — same logits, token for token.
#[test]
fn partial_verify_equals_full_verify_after_total_coverage_refresh() {
    let be = ReferenceBackend::new();
    let consts = be.consts().clone();
    let pv_cfg = SpecPvConfig { retrieval_budget: 256, ..SpecPvConfig::default() };

    let prompt = corpus::continuation_prompt(3, 150);
    let toks = tokenizer::encode(&prompt);
    let mut target = TargetSession::new(
        &be,
        "s",
        toks.len() + 2 * consts.tree_t,
        OffloadSim::new(Default::default()),
    )
    .unwrap();
    let (logits, _) = target.prefill(&toks, None, None).unwrap();
    let committed = target.cache.committed;
    assert_eq!(committed, toks.len());

    // gather a partial core with a budget that covers every valid block
    let mut partial = PartialSession::new(&be, "s", &pv_cfg).unwrap();
    let nb = target.bucket / consts.block;
    let nsel = partial.bucket / consts.block;
    let scores = target.score(8).unwrap();
    let plan =
        plan_gather(&scores, target.info.n_layer, nb, consts.block, committed, nsel, &pv_cfg);
    assert_eq!(
        plan.core_len, committed,
        "budget must cover the whole cache for this invariant"
    );
    let pstate = target.gather(&plan, partial.bucket).unwrap();
    partial.install(pstate, plan.core_len);

    // one draft chain as the tree (root = greedy next token)
    let root = specpv::sampling::argmax(&logits) as u32;
    let mut tree = Tree::new(root);
    let mut parent = 0;
    for t in [101u32, 110, 100, 32] {
        parent = tree.add(parent, t, -0.5);
    }
    let flat = tree.flatten(consts.tree_t);

    let read_p = partial.verify_tree(&flat, committed).unwrap();
    let read_f = target.verify_tree(&flat, committed).unwrap();
    let vocab = target.info.vocab;
    for row in 0..flat.n {
        let (lp, lf) = (read_p.logits(row), read_f.logits(row));
        for v in 0..vocab {
            assert!(
                (lp[v] - lf[v]).abs() <= 1e-5,
                "row {row} vocab {v}: partial {} vs full {}",
                lp[v],
                lf[v]
            );
        }
    }
}

#[test]
fn generate_with_is_byte_deterministic_across_runs_and_instances() {
    let cfg = Config { engine: EngineKind::SpecPv, ..base_cfg() };
    let prompt = corpus::continuation_prompt(7, 160);
    let req = GenRequest::greedy(tokenizer::encode(&prompt), 40);
    let be1 = ReferenceBackend::new();
    let a = engine::generate_with(&cfg, &be1, &req).unwrap();
    let b = engine::generate_with(&cfg, &be1, &req).unwrap();
    assert_eq!(a.tokens, b.tokens, "same backend, same seed → same bytes");
    let be2 = ReferenceBackend::new();
    let c = engine::generate_with(&cfg, &be2, &req).unwrap();
    assert_eq!(a.tokens, c.tokens, "fresh backend instance → same bytes");
    // and a different engine over the same backend is also stable
    let cfg_ar = Config { engine: EngineKind::Autoregressive, ..base_cfg() };
    let d = engine::generate_with(&cfg_ar, &be1, &req).unwrap();
    let e = engine::generate_with(&cfg_ar, &be2, &req).unwrap();
    assert_eq!(d.tokens, e.tokens);
}

#[test]
fn coordinator_runs_mixed_engines_on_reference_backend() {
    let be = ReferenceBackend::new();
    let mut coord = specpv::coordinator::Coordinator::new(&be, base_cfg());
    let p = corpus::continuation_prompt(21, 140);
    let mut ids = Vec::new();
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::SpecFull,
        EngineKind::SpecPv,
        EngineKind::TriForce,
        EngineKind::TokenSwift,
    ] {
        ids.push(
            coord
                .submit(GenRequest::greedy(tokenizer::encode(&p), 12), Some(kind))
                .unwrap(),
        );
    }
    coord.run_all();
    for id in ids {
        let tr = coord.get(id).unwrap();
        assert_eq!(
            tr.state,
            specpv::coordinator::RequestState::Done,
            "request {id}: {:?}",
            tr.state
        );
        assert!(!tr.result.as_ref().unwrap().tokens.is_empty());
    }
    assert_eq!(coord.registry.completed, 5);
    assert!(coord.registry.executions > 0, "backend counters not exported");
    let s = coord.registry.summary();
    assert!(s.contains("backend=reference"), "{s}");
}

#[test]
fn server_roundtrip_on_reference_backend() {
    let mut cfg = base_cfg();
    cfg.server_addr = "127.0.0.1:7921".into();
    std::thread::scope(|s| {
        let cfg2 = cfg.clone();
        let h = s.spawn(move || {
            // the server thread owns its backend (device handles !Send)
            let be = ReferenceBackend::new();
            let _ = specpv::server::serve(&be, cfg2);
        });
        let mut client = connect_retry("127.0.0.1:7921");
        let r = client.generate("Once upon a time, ", 12, "spec_full").unwrap();
        assert_eq!(r.get("ok").and_then(|x| x.as_bool()), Some(true), "{r:?}");
        assert!(r.get("text").and_then(|x| x.as_str()).is_some());
        let m = client.metrics().unwrap();
        assert_eq!(
            m.get("backend").and_then(|x| x.as_str()),
            Some("reference"),
            "{m:?}"
        );
        assert!(
            m.get("executions").and_then(|x| x.as_i64()).unwrap_or(0) > 0,
            "metrics op must expose backend execution counters: {m:?}"
        );
        client.shutdown().unwrap();
        h.join().unwrap();
    });
}

fn connect_retry(addr: &str) -> specpv::server::Client {
    for _ in 0..100 {
        if let Ok(c) = specpv::server::Client::connect(addr) {
            return c;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    panic!("server did not come up on {addr}");
}

#[test]
fn auto_backend_resolves_without_artifacts() {
    // `backend = auto` + a directory with no manifest → reference backend
    let cfg = Config {
        artifacts_dir: std::env::temp_dir().join("specpv_no_artifacts_here"),
        ..Config::default()
    };
    let be = specpv::backend::from_config(&cfg).unwrap();
    assert_eq!(be.name(), "reference");
}
