//! Continuous-batching scheduler tests over scripted (model-free)
//! sessions — no artifacts required, so these run everywhere (including
//! CI). The behaviours pinned here:
//!   * round-robin fairness: concurrent sessions interleave per tick
//!     rather than running head-of-line to completion
//!   * cancellation mid-generation frees the slot and keeps the partial
//!     output
//!   * admission rejection (prompt/max_new/queue limits)
//!   * deadlines expire in-flight requests
//!   * engine failures surface as Failed events, not panics
//!   * registry gauges + TTFT telemetry

use specpv::config::Config;
use specpv::coordinator::{Coordinator, Event, RequestId, RequestState, SubmitOpts};
use specpv::engine::scripted::ScriptedFactory;
use specpv::engine::GenRequest;

fn coord(max_active: usize, tokens_per_step: usize) -> Coordinator<'static> {
    let cfg = Config { max_active, ..Config::default() };
    let factory = ScriptedFactory { tokens_per_step, ..ScriptedFactory::default() };
    Coordinator::with_factory(cfg, Box::new(factory))
}

fn submit(c: &mut Coordinator<'static>, max_new: usize) -> RequestId {
    c.submit(GenRequest::greedy(vec![104, 105], max_new), None).unwrap()
}

/// Ids of Step events, in emission order.
fn step_ids(events: &[Event]) -> Vec<RequestId> {
    events
        .iter()
        .filter_map(|e| match e {
            Event::Step { id, .. } => Some(*id),
            _ => None,
        })
        .collect()
}

#[test]
fn round_robin_fairness_three_sessions() {
    let mut c = coord(3, 1);
    let ids = [submit(&mut c, 6), submit(&mut c, 6), submit(&mut c, 6)];
    let mut all = Vec::new();
    while !c.idle() {
        let evs = c.tick();
        // within a tick, each active session steps exactly once
        let sids = step_ids(&evs);
        let mut sorted = sids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), sids.len(), "a session stepped twice in one tick");
        all.extend(evs);
    }
    // every consecutive window of 3 steps covers all three sessions
    let sids = step_ids(&all);
    assert_eq!(sids.len(), 3 * 5, "6 tokens = 1 prefill + 5 steps each");
    for w in sids.chunks(3) {
        let mut ws = w.to_vec();
        ws.sort_unstable();
        assert_eq!(ws, ids.to_vec(), "unfair window: {sids:?}");
    }
    for id in ids {
        let tr = c.get(id).unwrap();
        assert_eq!(tr.state, RequestState::Done);
        assert_eq!(tr.result.as_ref().unwrap().tokens.len(), 6);
    }
    assert_eq!(c.registry.completed, 3);
    assert_eq!(c.registry.ttft.len(), 3);
}

/// The acceptance-criterion shape: two concurrent requests finish with
/// interleaved step counts rather than sequential completion.
#[test]
fn two_concurrent_requests_interleave() {
    let mut c = coord(2, 1);
    let a = submit(&mut c, 8);
    let b = submit(&mut c, 8);
    let mut events = Vec::new();
    while !c.idle() {
        events.extend(c.tick());
    }
    let sids = step_ids(&events);
    // b makes progress strictly before a finishes (and vice versa):
    let a_last = sids.iter().rposition(|&i| i == a).unwrap();
    let b_first = sids.iter().position(|&i| i == b).unwrap();
    let b_last = sids.iter().rposition(|&i| i == b).unwrap();
    let a_first = sids.iter().position(|&i| i == a).unwrap();
    assert!(b_first < a_last, "sequential completion, no interleave: {sids:?}");
    assert!(a_first < b_last, "sequential completion, no interleave: {sids:?}");
    // both completed with the same number of scheduler steps
    assert_eq!(c.get(a).unwrap().steps, c.get(b).unwrap().steps);
    assert_eq!(c.registry.completed, 2);
}

#[test]
fn admission_waits_for_free_slot() {
    let mut c = coord(1, 1);
    let a = submit(&mut c, 4);
    let b = submit(&mut c, 4);
    let mut started = Vec::new();
    let mut finished = Vec::new();
    while !c.idle() {
        for e in c.tick() {
            match e {
                Event::Started { id } => started.push(id),
                Event::Finished { id } => finished.push(id),
                _ => {}
            }
        }
    }
    assert_eq!(started, vec![a, b]);
    assert_eq!(finished, vec![a, b]);
    // with max_active=1 the second request starts only after the first
    // finishes — verified by the registry having recorded queue wait
    assert_eq!(c.registry.completed, 2);
    assert!(c.get(b).unwrap().queued_secs >= c.get(a).unwrap().queued_secs);
}

#[test]
fn cancellation_mid_generation() {
    let mut c = coord(2, 1);
    let id = submit(&mut c, 100);
    c.tick(); // admit + step 1
    c.tick();
    assert_eq!(c.active_len(), 1);
    assert!(c.cancel(id), "cancel running request");
    let tr = c.get(id).unwrap();
    assert_eq!(tr.state, RequestState::Cancelled);
    let partial = tr.result.as_ref().expect("partial result kept");
    assert!(!partial.tokens.is_empty() && partial.tokens.len() < 100);
    assert_eq!(c.active_len(), 0, "slot freed");
    assert_eq!(c.registry.cancelled, 1);
    // double-cancel and cancel of unknown ids are no-ops
    assert!(!c.cancel(id));
    assert!(!c.cancel(999));
}

#[test]
fn cancellation_of_queued_request() {
    let mut c = coord(1, 1);
    let a = submit(&mut c, 50);
    let b = submit(&mut c, 50);
    c.tick(); // admits a only
    assert!(c.cancel(b));
    assert_eq!(c.get(b).unwrap().state, RequestState::Cancelled);
    c.run_all();
    assert_eq!(c.get(a).unwrap().state, RequestState::Done);
    assert_eq!(c.registry.completed, 1);
    assert_eq!(c.registry.cancelled, 1);
}

#[test]
fn admission_rejection() {
    let mut c = coord(2, 1);
    // oversized prompt
    let huge = vec![65u32; 100_000];
    assert!(c.submit(GenRequest::greedy(huge, 16), None).is_err());
    // oversized max_new
    assert!(c.submit(GenRequest::greedy(vec![65; 10], 1 << 20), None).is_err());
    // queue overflow
    c.admission.max_queue = 2;
    submit(&mut c, 4);
    submit(&mut c, 4);
    assert!(c.submit(GenRequest::greedy(vec![65; 10], 4), None).is_err());
    assert_eq!(c.queue_len(), 2);
    c.run_all();
    assert_eq!(c.registry.completed, 2);
}

#[test]
fn deadline_expires_request() {
    let mut c = coord(2, 1);
    let id = c
        .submit_with_deadline(GenRequest::greedy(vec![65; 4], 500), None, Some(0.0))
        .unwrap();
    let ok = submit(&mut c, 4);
    let mut failed = Vec::new();
    while !c.idle() {
        for e in c.tick() {
            if let Event::DeadlineExceeded { id } = e {
                failed.push(id);
            }
        }
    }
    assert_eq!(failed, vec![id]);
    match &c.get(id).unwrap().state {
        RequestState::Failed(e) => assert!(e.contains("deadline"), "{e}"),
        other => panic!("expected deadline failure, got {other:?}"),
    }
    assert_eq!(c.get(ok).unwrap().state, RequestState::Done);
    assert_eq!(c.registry.failed, 1);
    assert_eq!(c.registry.deadline_hits, 1);
    assert_eq!(c.registry.completed, 1);
}

#[test]
fn engine_failure_is_contained() {
    let cfg = Config { max_active: 2, ..Config::default() };
    let factory = ScriptedFactory {
        tokens_per_step: 1,
        fail_step_marker: Some(666),
        ..ScriptedFactory::default()
    };
    let mut c = Coordinator::with_factory(cfg, Box::new(factory));
    let bad = c.submit(GenRequest::greedy(vec![666], 8), None).unwrap();
    let good = c.submit(GenRequest::greedy(vec![65], 8), None).unwrap();
    c.run_all();
    assert!(matches!(c.get(bad).unwrap().state, RequestState::Failed(_)));
    assert_eq!(c.get(good).unwrap().state, RequestState::Done);
    assert_eq!(c.registry.failed, 1);
    assert_eq!(c.registry.completed, 1);
}

#[test]
fn start_failure_is_contained() {
    let cfg = Config { max_active: 2, ..Config::default() };
    let factory = ScriptedFactory {
        tokens_per_step: 1,
        fail_start_marker: Some(666),
        ..ScriptedFactory::default()
    };
    let mut c = Coordinator::with_factory(cfg, Box::new(factory));
    let bad = c.submit(GenRequest::greedy(vec![666], 8), None).unwrap();
    c.run_all();
    assert!(matches!(c.get(bad).unwrap().state, RequestState::Failed(_)));
}

#[test]
fn run_until_leaves_others_in_flight() {
    let mut c = coord(2, 1);
    let a = submit(&mut c, 4);
    let b = submit(&mut c, 64);
    c.run_until(a);
    assert_eq!(c.get(a).unwrap().state, RequestState::Done);
    // b was co-scheduled and has made progress, but is not done
    let b_tr = c.get(b).unwrap();
    assert_eq!(b_tr.state, RequestState::Running);
    assert!(b_tr.steps > 0);
    c.run_all();
    assert_eq!(c.get(b).unwrap().state, RequestState::Done);
}

#[test]
fn registry_gauges_track_queue_and_active() {
    let mut c = coord(1, 1);
    submit(&mut c, 8);
    submit(&mut c, 8);
    submit(&mut c, 8);
    assert_eq!(c.registry.queue_depth, 3);
    c.tick();
    assert_eq!(c.registry.active_sessions, 1);
    assert_eq!(c.registry.queue_depth, 2);
    c.run_all();
    assert_eq!(c.registry.queue_depth, 0);
    assert_eq!(c.registry.active_sessions, 0);
    let s = c.registry.summary();
    assert!(s.contains("completed=3"), "{s}");
    assert!(s.contains("p50_ttft="), "{s}");
}

/// Coordinator with a KV byte budget that fits exactly one scripted
/// session at a time (each reports 100 synthetic bytes).
fn kv_coord(kv_budget_bytes: usize) -> Coordinator<'static> {
    let cfg = Config { max_active: 4, kv_budget_bytes, ..Config::default() };
    let factory =
        ScriptedFactory { tokens_per_step: 1, session_bytes: 100, ..ScriptedFactory::default() };
    Coordinator::with_factory(cfg, Box::new(factory))
}

fn submit_prio(c: &mut Coordinator<'static>, max_new: usize, priority: i32) -> RequestId {
    c.submit_opts(
        GenRequest::greedy(vec![104, 105], max_new),
        SubmitOpts { priority, ..SubmitOpts::default() },
    )
    .unwrap()
}

#[test]
fn byte_budget_gates_admission_without_priorities() {
    // budget fits one session; equal priorities → no preemption, the
    // second request simply waits (head-of-line, not starvation: it
    // starts as soon as the first finishes)
    let mut c = kv_coord(150);
    let a = submit_prio(&mut c, 4, 0);
    let b = submit_prio(&mut c, 4, 0);
    c.tick();
    assert_eq!(c.active_len(), 1, "only one session fits the byte budget");
    assert_eq!(c.registry.kv_resident_bytes, 100);
    let mut started = Vec::new();
    while !c.idle() {
        for e in c.tick() {
            if let Event::Started { id } = e {
                started.push(id);
            }
        }
    }
    assert_eq!(started, vec![b], "b started only after a finished");
    assert_eq!(c.registry.swap_outs, 0, "equal priority never preempts");
    assert_eq!(c.get(a).unwrap().state, RequestState::Done);
    assert_eq!(c.get(b).unwrap().state, RequestState::Done);
    assert_eq!(c.registry.kv_resident_bytes, 0, "pool drains at idle");
}

#[test]
fn higher_priority_preempts_and_victim_resumes() {
    let mut c = kv_coord(150);
    let low = submit_prio(&mut c, 12, 0);
    c.tick();
    c.tick();
    assert_eq!(c.active_len(), 1);
    let high = submit_prio(&mut c, 4, 5);
    let mut swapped = Vec::new();
    let mut resumed = Vec::new();
    while !c.idle() {
        for e in c.tick() {
            match e {
                Event::SwappedOut { id } => {
                    swapped.push(id);
                    assert_eq!(
                        c.get(id).unwrap().state,
                        RequestState::Swapped
                    );
                }
                Event::Resumed { id } => resumed.push(id),
                _ => {}
            }
        }
    }
    assert_eq!(swapped, vec![low], "the low-priority session is the victim");
    assert_eq!(resumed, vec![low]);
    assert_eq!((c.registry.swap_outs, c.registry.swap_ins), (1, 1));
    // both completed in full — swapping lost no output
    for (id, max_new) in [(low, 12), (high, 4)] {
        let tr = c.get(id).unwrap();
        assert_eq!(tr.state, RequestState::Done);
        assert_eq!(tr.result.as_ref().unwrap().tokens.len(), max_new);
    }
    let s = c.registry.summary();
    assert!(s.contains("swaps=1/1"), "{s}");
    assert!(s.contains("kv_budget=150"), "{s}");
}

#[test]
fn swapped_request_can_be_cancelled_with_partial_output() {
    let mut c = kv_coord(150);
    let low = submit_prio(&mut c, 50, 0);
    c.tick();
    c.tick();
    let _high = submit_prio(&mut c, 50, 5);
    c.tick(); // preempts low, admits high
    assert_eq!(c.get(low).unwrap().state, RequestState::Swapped);
    assert!(c.cancel(low));
    let tr = c.get(low).unwrap();
    assert_eq!(tr.state, RequestState::Cancelled);
    let partial = tr.result.as_ref().expect("partial output kept");
    assert!(!partial.tokens.is_empty() && partial.tokens.len() < 50);
    c.run_all();
    assert_eq!(c.registry.cancelled, 1);
    assert_eq!(c.registry.completed, 1);
    assert_eq!(c.registry.kv_resident_bytes, 0);
}

/// Batched-execution fallback (DESIGN.md §12): scripted sessions do not
/// implement the plan/apply protocol, so the wave loop must degrade to
/// exactly the old sequential rotation — commit order (per-tick Step
/// emission order) follows the rotation cursor, no session ever steps
/// twice in a tick or starves, and the occupancy metrics report the
/// sequential fallback rather than phantom fused groups.
#[test]
fn grouping_never_reorders_commit_order_or_starves_scripted_sessions() {
    let mut c = coord(3, 1);
    // mixed lengths so sessions retire at different ticks
    let ids = [submit(&mut c, 4), submit(&mut c, 8), submit(&mut c, 6)];
    let mut per_tick: Vec<Vec<RequestId>> = Vec::new();
    while !c.idle() {
        per_tick.push(step_ids(&c.tick()));
    }
    // per tick: unique sessions, and the emission order is a rotation of
    // the currently-active id set (never an arbitrary reorder)
    for (t, ids_t) in per_tick.iter().enumerate() {
        let mut sorted = ids_t.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ids_t.len(), "tick {t}: a session stepped twice");
        if ids_t.len() > 1 {
            let min_pos = ids_t.iter().position(|i| *i == *ids_t.iter().min().unwrap());
            let rotated: Vec<RequestId> = (0..ids_t.len())
                .map(|k| ids_t[(min_pos.unwrap() + k) % ids_t.len()])
                .collect();
            let mut expect = rotated.clone();
            expect.sort_unstable();
            assert_eq!(
                rotated,
                expect,
                "tick {t}: emission order {ids_t:?} is not a rotation of the active set"
            );
        }
    }
    // no starvation: every session steps every tick until it finishes
    for w in per_tick.windows(2) {
        for id in &w[1] {
            assert!(w[0].contains(id), "session {id} skipped a tick: {per_tick:?}");
        }
    }
    for id in ids {
        assert_eq!(c.get(id).unwrap().state, RequestState::Done);
    }
    // occupancy metrics: scripted sessions are sequential-fallback steps
    assert_eq!(c.registry.batch_groups, 0, "scripted sessions cannot fuse");
    assert!(c.registry.fallback_steps > 0, "fallback steps must be counted");
    assert_eq!(c.registry.batch_ops_single, 0, "no protocol ops ran");
    assert_eq!(c.registry.batched_frac(), 0.0);
    let s = c.registry.summary();
    assert!(s.contains("fused_groups=0"), "{s}");
    assert!(s.contains("threads="), "{s}");
}

/// Byte-level check that the scripted engine respects max_new exactly
/// (the SessionOut clipping that also fixes the tau accounting).
#[test]
fn emitted_tokens_respect_max_new() {
    let mut c = coord(1, 3);
    let id = submit(&mut c, 10);
    c.run_all();
    let r = c.get(id).unwrap().result.as_ref().unwrap().clone();
    assert_eq!(r.tokens.len(), 10);
    assert_eq!(r.stats.new_tokens, 10);
    // accepted_total only counts kept drafted tokens: 9 post-prefill
    // tokens over 3-token rounds = 3 steps × ≤2 drafted
    assert!(r.stats.accepted_total <= 2 * r.stats.verify_steps);
}

/// Page-gauge accounting: a page referenced by several block tables
/// (prefix sharing) and byte-identical parks that dedup to the same
/// physical page must count **once** in `kv_pages_resident` —
/// `Registry` reports physical pages, not the sum of block-table
/// lengths.
#[test]
fn shared_prefix_pages_are_not_double_counted() {
    use specpv::backend::StateKind;

    let mut c = coord(1, 1);
    // a two-page image (non-zero so dedup is content-hash, not the
    // zero-page fast path)
    let elems = c.pool.stats().page_bytes / 4;
    let data: Vec<f32> = (0..elems + 7).map(|i| (i as f32) + 0.5).collect();
    let a = c.pool.park_image(StateKind::Full, "s", 64, &data, &[]);
    let physical = c.pool.stats().pages_resident;
    assert!(physical >= 2, "image should span at least two pages");

    // share into a second block table: same physical pages
    let b = c.pool.share_state(&a);
    // park the same bytes again: content dedup, still the same pages
    let d = c.pool.park_image(StateKind::Full, "s", 64, &data, &[]);

    c.tick();
    assert_eq!(
        c.registry.kv_pages_resident, physical,
        "three block tables over one image must not inflate residency"
    );
    assert!(
        c.registry.kv_pages_shared >= physical,
        "every page is multiply referenced and must show as shared"
    );
    let summary = c.registry.summary();
    assert!(summary.contains("kv_pages="), "{summary}");

    // dropping the extra references returns to sole ownership…
    c.pool.free_state(&b);
    c.pool.free_state(&d);
    c.tick();
    assert_eq!(c.registry.kv_pages_resident, physical);
    assert_eq!(c.registry.kv_pages_shared, 0);
    // …and freeing the last table drains the pool
    c.pool.free_state(&a);
    c.tick();
    assert_eq!(c.registry.kv_pages_resident, 0);
}
