//! Fault-tolerance acceptance tests (DESIGN.md §15): forced shard
//! panics mid-stream with byte-identical completion on both failover
//! paths (checkpoint resume and deterministic regeneration), deadline
//! and overload control producing exactly one structured terminal line,
//! dead-connection reaping of parked requests, and a 256-client chaos
//! soak under active failpoints with zero lost or duplicated wire
//! lines and a drained KV pool.

use std::collections::HashSet;
use std::net::TcpListener;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use specpv::config::{Config, EngineKind, PolicyConfig, PolicyMode};
use specpv::coordinator::{Coordinator, SubmitOpts};
use specpv::engine::scripted::{ScriptedFactory, SpecSim};
use specpv::engine::GenRequest;
use specpv::json::Json;
use specpv::serve::serve_scripted;
use specpv::server::Client;
use specpv::tokenizer;

/// Drive one request through a bare coordinator to completion — the
/// undisturbed pin every failover path must match byte for byte.
fn direct_run(factory: ScriptedFactory, cfg: Config, prompt: &str, max_new: usize) -> String {
    let mut coord = Coordinator::with_factory(cfg, Box::new(factory));
    let req = GenRequest::greedy(tokenizer::encode(prompt), max_new);
    let id = coord.submit(req, Some(EngineKind::SpecPv)).unwrap();
    while !coord.idle() {
        coord.tick();
    }
    let tr = coord.get(id).unwrap();
    tr.result.as_ref().expect("direct run must complete").text()
}

fn delta_concat(steps: &[Json]) -> String {
    steps.iter().filter_map(|j| j.get("delta").and_then(|x| x.as_str())).collect()
}

fn num(j: &Json, key: &str) -> i64 {
    j.get(key).and_then(|x| x.as_i64()).unwrap_or_else(|| panic!("{key} missing: {j:?}"))
}

/// A shard panic mid-stream fails the session over to the restarted
/// shard via its last periodic checkpoint; the client's stream resumes
/// where it left off and the final text is byte-identical to an
/// undisturbed run.
#[test]
fn checkpoint_failover_resumes_byte_identical() {
    let factory = ScriptedFactory { tokens_per_step: 2, ..ScriptedFactory::default() };
    let want = direct_run(factory.clone(), Config::default(), "failover pin alpha", 40);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = Config {
        shards: 1,
        checkpoint_every_steps: 2,
        faults: "shard_panic@step=6".into(),
        ..Config::default()
    };
    let server = thread::spawn(move || serve_scripted(listener, cfg, factory));

    let mut cl = Client::connect(&addr).unwrap();
    let (steps, fin) = cl.generate_stream("failover pin alpha", 40, "spec_pv").unwrap();
    assert_eq!(fin.get("ok").and_then(|x| x.as_bool()), Some(true), "{fin:?}");
    assert_eq!(fin.get("tokens").and_then(|x| x.as_usize()), Some(40));
    assert_eq!(fin.get("text").and_then(|x| x.as_str()), Some(want.as_str()));
    // zero lost or duplicated lines across the failover
    assert_eq!(delta_concat(&steps), want);

    let m = cl.admin("metrics").unwrap();
    assert_eq!(num(&m, "restarts"), 1, "{m:?}");
    assert_eq!(num(&m, "checkpoint_resumes"), 1, "{m:?}");
    assert_eq!(num(&m, "failover_checkpoint"), 1, "{m:?}");
    assert_eq!(num(&m, "failover_regen"), 0, "{m:?}");
    assert_eq!(num(&m, "deadline_hits"), 0, "{m:?}");
    assert_eq!(num(&m, "parked_requests"), 0, "{m:?}");
    assert_eq!(num(&m, "retained_checkpoints"), 0, "{m:?}");
    cl.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

/// With checkpointing off, failover deterministically regenerates from
/// the prompt; the already-delivered prefix is suppressed, not
/// duplicated, and the final text still matches the undisturbed run.
#[test]
fn regenerate_failover_is_byte_identical() {
    let factory = ScriptedFactory { tokens_per_step: 2, ..ScriptedFactory::default() };
    let want = direct_run(factory.clone(), Config::default(), "failover pin beta", 40);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = Config {
        shards: 1,
        checkpoint_every_steps: 0,
        faults: "shard_panic@step=6".into(),
        ..Config::default()
    };
    let server = thread::spawn(move || serve_scripted(listener, cfg, factory));

    let mut cl = Client::connect(&addr).unwrap();
    let (steps, fin) = cl.generate_stream("failover pin beta", 40, "spec_pv").unwrap();
    assert_eq!(fin.get("ok").and_then(|x| x.as_bool()), Some(true), "{fin:?}");
    assert_eq!(fin.get("tokens").and_then(|x| x.as_usize()), Some(40));
    assert_eq!(fin.get("text").and_then(|x| x.as_str()), Some(want.as_str()));
    assert_eq!(delta_concat(&steps), want);

    let m = cl.admin("metrics").unwrap();
    assert_eq!(num(&m, "restarts"), 1, "{m:?}");
    assert_eq!(num(&m, "checkpoint_resumes"), 0, "{m:?}");
    assert_eq!(num(&m, "failover_checkpoint"), 0, "{m:?}");
    assert_eq!(num(&m, "failover_regen"), 1, "{m:?}");
    cl.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

/// A request that overruns its `timeout_ms` gets exactly one structured
/// terminal line — and nothing after it.
#[test]
fn deadline_exceeded_is_one_structured_terminal_line() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = Config::default();
    let factory = ScriptedFactory {
        tokens_per_step: 1,
        step_micros: 20_000,
        ..ScriptedFactory::default()
    };
    let server = thread::spawn(move || serve_scripted(listener, cfg, factory));

    let mut cl = Client::connect(&addr).unwrap();
    cl.send(
        Json::obj()
            .set("op", "generate")
            .set("prompt", "deadline probe")
            .set("max_new", 4096usize)
            .set("engine", "ar")
            .set("stream", true)
            .set("timeout_ms", 100i64),
    )
    .unwrap();
    let fin = loop {
        let j = cl.recv().unwrap();
        if j.get("done").and_then(|x| x.as_bool()) == Some(true)
            || j.get("ok").and_then(|x| x.as_bool()) == Some(false)
        {
            break j;
        }
    };
    assert_eq!(fin.get("ok").and_then(|x| x.as_bool()), Some(false), "{fin:?}");
    assert_eq!(fin.get("done").and_then(|x| x.as_bool()), Some(true), "{fin:?}");
    assert_eq!(fin.get("deadline_exceeded").and_then(|x| x.as_bool()), Some(true), "{fin:?}");
    let err = fin.get("error").and_then(|x| x.as_str()).unwrap_or_default();
    assert!(err.contains("deadline"), "{fin:?}");
    // the terminal line is the last line for this request: the next
    // thing the server sends on this connection is the ping reply
    let pong = cl.call(Json::obj().set("op", "ping")).unwrap();
    assert_eq!(pong.get("ok").and_then(|x| x.as_bool()), Some(true), "{pong:?}");
    assert!(pong.get("id").is_none(), "stray line after terminal: {pong:?}");

    let m = cl.admin("metrics").unwrap();
    assert_eq!(num(&m, "deadline_hits"), 1, "{m:?}");
    cl.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

/// A generate bound for a full shard is shed with exactly one
/// structured rejection (no id, no final line); the retrying client
/// backs off per `retry_after_ms` and eventually succeeds.
#[test]
fn overload_shed_is_structured_and_retry_recovers() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = Config { shards: 1, shard_queue: 1, ..Config::default() };
    let factory = ScriptedFactory {
        tokens_per_step: 1,
        step_micros: 3_000,
        ..ScriptedFactory::default()
    };
    let server = thread::spawn(move || serve_scripted(listener, cfg, factory));

    // occupy the shard's only queue slot with a slow streaming session
    let mut a = Client::connect(&addr).unwrap();
    a.send(
        Json::obj()
            .set("op", "generate")
            .set("prompt", "occupant")
            .set("max_new", 100usize)
            .set("engine", "ar")
            .set("stream", true),
    )
    .unwrap();
    let ack = a.recv().unwrap();
    assert_eq!(ack.get("queued").and_then(|x| x.as_bool()), Some(true), "{ack:?}");

    let mut b = Client::connect(&addr).unwrap();
    let shed = b.generate("latecomer", 8, "ar").unwrap();
    assert_eq!(shed.get("ok").and_then(|x| x.as_bool()), Some(false), "{shed:?}");
    assert_eq!(shed.get("error").and_then(|x| x.as_str()), Some("overloaded"), "{shed:?}");
    assert!(num(&shed, "retry_after_ms") >= 1, "{shed:?}");
    assert!(shed.get("id").is_none(), "a shed request must not burn an id: {shed:?}");

    // the retry helper honors retry_after_ms and lands once A drains
    let fin = b.generate_retry("latecomer", 8, "ar", 1).unwrap();
    assert_eq!(fin.get("ok").and_then(|x| x.as_bool()), Some(true), "{fin:?}");
    assert_eq!(fin.get("tokens").and_then(|x| x.as_usize()), Some(8));

    // A's stream was untouched by the shedding
    let fin_a = loop {
        let j = a.recv().unwrap();
        if j.get("done").and_then(|x| x.as_bool()) == Some(true) {
            break j;
        }
    };
    assert_eq!(fin_a.get("ok").and_then(|x| x.as_bool()), Some(true), "{fin_a:?}");

    let m = b.admin("metrics").unwrap();
    assert!(num(&m, "shed_requests") >= 1, "{m:?}");
    b.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

/// The backend-error failpoint surfaces as a clean request failure —
/// one structured error line, nothing wedged.
#[test]
fn injected_backend_error_fails_request_cleanly() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = Config { faults: "backend_err_rate=1,seed=3".into(), ..Config::default() };
    let factory = ScriptedFactory::default();
    let server = thread::spawn(move || serve_scripted(listener, cfg, factory));

    let mut cl = Client::connect(&addr).unwrap();
    let fin = cl.generate("doomed", 16, "ar").unwrap();
    assert_eq!(fin.get("ok").and_then(|x| x.as_bool()), Some(false), "{fin:?}");
    let err = fin.get("error").and_then(|x| x.as_str()).unwrap_or_default();
    assert!(err.contains("injected backend error"), "{fin:?}");
    cl.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

/// Regression: a queued-but-unrouted (parked) request whose connection
/// dies must be released by the reaper, not leak in the park queue.
#[test]
fn dead_connection_reap_releases_parked_requests() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // one shard, zero restart budget: after the forced panic the shard
    // dead-ends and the failed-over session stays parked forever
    let cfg = Config {
        shards: 1,
        max_restarts: 0,
        faults: "shard_panic@step=2".into(),
        ..Config::default()
    };
    let factory = ScriptedFactory::default();
    let server = thread::spawn(move || serve_scripted(listener, cfg, factory));

    let mut victim = Client::connect(&addr).unwrap();
    victim
        .send(
            Json::obj()
                .set("op", "generate")
                .set("prompt", "parked forever")
                .set("max_new", 50usize)
                .set("engine", "ar")
                .set("stream", true),
        )
        .unwrap();
    let ack = victim.recv().unwrap();
    assert_eq!(ack.get("queued").and_then(|x| x.as_bool()), Some(true), "{ack:?}");

    let mut admin = Client::connect(&addr).unwrap();
    let parked = |admin: &mut Client, want: i64| {
        for _ in 0..100 {
            let m = admin.admin("metrics").unwrap();
            if m.get("parked_requests").and_then(|x| x.as_i64()) == Some(want) {
                return;
            }
            thread::sleep(Duration::from_millis(50));
        }
        panic!("parked_requests never reached {want}");
    };
    // the panic fails the session over; with no restart budget it parks
    parked(&mut admin, 1);
    // the owner disconnects; the reaper must release the parked entry
    drop(victim);
    parked(&mut admin, 0);
    admin.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

/// Regression (DESIGN.md §16): a `checkpoint_every_steps` checkpoint
/// must carry the session's learned `PolicyState`, and a failed-over
/// session must resume with the learned draft depth instead of
/// relearning from the config default.
#[test]
fn checkpoint_carries_policy_state_and_failover_resumes_learned_depth() {
    let policy = PolicyConfig {
        mode: PolicyMode::Adaptive,
        draft_min: 1,
        draft_max: 6,
        alpha: 0.5,
        grow: 0.8,
        shrink: 0.35,
        adjust_every: 1,
        ..PolicyConfig::default()
    };
    let cfg = Config {
        engine: EngineKind::SpecPv,
        max_active: 1,
        policy,
        ..Config::default()
    };
    // steady full acceptance: the controller grows depth 2 → draft_max
    let factory = ScriptedFactory {
        spec: Some(SpecSim { accepts: vec![6], depth: 2, ..SpecSim::default() }),
        ..ScriptedFactory::default()
    };
    let req = GenRequest::greedy(vec![11, 12, 13], 200);

    let mut a = Coordinator::with_factory(cfg.clone(), Box::new(factory.clone()));
    let id = a.submit(req.clone(), None).unwrap();
    for _ in 0..12 {
        a.tick();
    }
    let ck = a.checkpoint(id).expect("mid-flight checkpoint");
    let ps = ck.policy.clone().expect("checkpoint must carry PolicyState");
    assert!(
        ps.depth > 2,
        "controller never grew depth before the checkpoint (depth={})",
        ps.depth
    );
    let learned = ps.depth;
    assert!(ps.rounds > 0 && ps.accept_ewma > 0.0);

    // fail the session over to a fresh coordinator (a restarted shard)
    let mut b = Coordinator::with_factory(cfg, Box::new(factory));
    let id2 = b
        .submit_failover(req, SubmitOpts::default(), Some(ck.clone()))
        .unwrap();
    b.tick(); // admit + resume
    let resumed = b.policy.state(id2).expect("restored policy state");
    assert_eq!(
        resumed.depth, learned,
        "failed-over session did not resume with the learned depth"
    );
    while !b.idle() {
        b.tick();
    }
    let tr = b.get(id2).unwrap();
    let got = &tr.result.as_ref().expect("failover run completes").tokens;
    // position-indexed stream → byte-identical to an undisturbed run
    let want: Vec<u32> = (0..200).map(|i| (b'a' + (i % 26) as u8) as u32).collect();
    assert_eq!(got, &want);
    assert_eq!(tr.resumed_tokens, ck.emitted.len());
}

const CHAOS_CLIENTS: usize = 256;

/// Streaming generate with a priority and the overload retry loop
/// (priorities drive KV-pressure preemption, so swapped-out sessions
/// are also in flight when the shard panic fires).
fn stream_retry_priority(
    cl: &mut Client,
    prompt: &str,
    max_new: usize,
    engine: &str,
    priority: i64,
    seed: u64,
) -> (Vec<Json>, Json) {
    let mut jitter = 40 + seed % 60;
    for _ in 0..24 {
        cl.send(
            Json::obj()
                .set("op", "generate")
                .set("prompt", prompt)
                .set("max_new", max_new)
                .set("engine", engine)
                .set("priority", priority)
                .set("stream", true),
        )
        .unwrap();
        let mut steps = Vec::new();
        let fin = loop {
            let j = cl.recv().unwrap();
            if j.get("done").and_then(|x| x.as_bool()) == Some(true)
                || j.get("ok").and_then(|x| x.as_bool()) == Some(false)
            {
                break j;
            }
            steps.push(j);
        };
        if fin.get("error").and_then(|x| x.as_str()) != Some("overloaded") {
            return (steps, fin);
        }
        let hint = fin.get("retry_after_ms").and_then(|x| x.as_f64()).unwrap_or(50.0) as u64;
        thread::sleep(Duration::from_millis((hint + jitter).min(500)));
        jitter = jitter * 2 % 97 + 40;
    }
    panic!("still shed after 24 attempts");
}

/// 256 streaming clients across 2 shards under active failpoints
/// (per-shard panics, probabilistic backend errors), tight KV bytes
/// with mixed priorities (preemption churn), and a bounded shard queue
/// (shedding + client retry). Ends with zero lost or duplicated wire
/// lines, a drained KV pool, and no leaked park/checkpoint state.
#[test]
fn chaos_soak_256_clients_with_failpoints() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = Config {
        max_active: 8,
        shards: 2,
        checkpoint_every_steps: 1,
        shard_queue: 32,
        kv_budget_bytes: 64 * 1024,
        faults: "shard_panic@step=59,backend_err_rate=0.002,swap_corrupt_rate=0.3,seed=9".into(),
        ..Config::default()
    };
    let factory = ScriptedFactory {
        tokens_per_step: 2,
        step_micros: 200,
        session_bytes: 16 * 1024,
        ..ScriptedFactory::default()
    };
    let server = thread::spawn(move || serve_scripted(listener, cfg, factory));

    // the scripted stream is position-indexed: every undisturbed (and
    // therefore every correctly failed-over) 24-token output is this
    let want: String = (0..24u8).map(|i| (b'a' + i % 26) as char).collect();

    let ids = Arc::new(Mutex::new(HashSet::<u64>::new()));
    let failures = Arc::new(Mutex::new(0usize));
    let mut clients = Vec::new();
    for c in 0..CHAOS_CLIENTS {
        let addr = addr.clone();
        let ids = ids.clone();
        let failures = failures.clone();
        let want = want.clone();
        clients.push(thread::spawn(move || {
            let mut cl = Client::connect(&addr).unwrap();
            let prompt = format!("chaos client {c} prompt payload");
            let (steps, fin) =
                stream_retry_priority(&mut cl, &prompt, 24, "ar", (c % 3) as i64, c as u64);
            let id = fin
                .get("id")
                .and_then(|x| x.as_i64())
                .unwrap_or_else(|| panic!("terminal line without id: {fin:?}"));
            assert!(ids.lock().unwrap().insert(id as u64), "duplicate wire id {id}");
            if fin.get("ok").and_then(|x| x.as_bool()) == Some(true) {
                assert_eq!(fin.get("tokens").and_then(|x| x.as_usize()), Some(24), "{fin:?}");
                assert_eq!(
                    fin.get("text").and_then(|x| x.as_str()),
                    Some(want.as_str()),
                    "non-deterministic recovery for client {c}"
                );
                // zero lost or duplicated stream lines, across panics,
                // failovers, preemption and re-queued fresh runs
                assert_eq!(
                    delta_concat(&steps),
                    want,
                    "lost/dup stream lines for client {c}: {fin:?}"
                );
            } else {
                // the only legal failure under this fault spec is the
                // injected backend error
                let err = fin.get("error").and_then(|x| x.as_str()).unwrap_or_default();
                assert!(err.contains("injected backend error"), "{fin:?}");
                *failures.lock().unwrap() += 1;
            }
        }));
    }
    for t in clients {
        t.join().unwrap();
    }
    assert_eq!(
        ids.lock().unwrap().len(),
        CHAOS_CLIENTS,
        "every client got exactly one terminal line with a unique id"
    );

    let mut admin = Client::connect(&addr).unwrap();
    let m = admin.admin("metrics").unwrap();
    assert!(num(&m, "restarts") >= 1, "no supervised restart happened: {m:?}");
    assert!(
        num(&m, "checkpoint_resumes") >= 1,
        "no session resumed from a failover checkpoint: {m:?}"
    );
    assert!(
        num(&m, "failover_checkpoint") + num(&m, "failover_regen") >= 1,
        "no session was failed over: {m:?}"
    );
    assert_eq!(num(&m, "parked_requests"), 0, "leaked parked requests: {m:?}");
    assert_eq!(num(&m, "retained_checkpoints"), 0, "leaked checkpoints: {m:?}");
    // the pool drains completely once every session terminated
    let kv = admin.admin("kv").unwrap();
    assert_eq!(num(&kv, "pages_resident"), 0, "{kv:?}");
    admin.shutdown().unwrap();
    server.join().unwrap().unwrap();
}
