//! Batched ≡ sequential **byte parity** (DESIGN.md §12).
//!
//! The batched backend ops and the coordinator's cross-session fusion
//! must be invisible in the output: executing a group of kernel ops as
//! one fused invocation has to leave every state — and every token ever
//! decoded from it — bit-identical to executing the ops one at a time,
//! at any batch size and thread count. Pinned here:
//!
//!   * op-level parity for every batchable op class
//!     (prefill/verify_full/verify_partial/draft_expand/tiny_forward)
//!     over mixed per-session kv_lens, at 1 and 4 threads;
//!   * generation-level parity: mixed-engine concurrent sessions over
//!     the batching coordinator ≡ `generate_with` (single-session) ≡ the
//!     same coordinator with batching disabled, including the event
//!     stream (commit order) and the rotation-fairness shape;
//!   * the occupancy metrics actually observe fusion.

use specpv::backend::reference::ReferenceBackend;
use specpv::backend::{
    Backend, DraftExpandOp, DraftPrefillOp, PrefillOp, StateBuf, StateKind, TinyForwardOp,
    VerifyOp,
};
use specpv::config::{BackendKind, Config, EngineKind, SpecPvConfig};
use specpv::coordinator::{Coordinator, Event, RequestId};
use specpv::engine::{self, GenRequest};
use specpv::{corpus, tokenizer, tree};

const SIZE: &str = "s";
const BUCKET: usize = 512;

fn base_cfg() -> Config {
    Config {
        backend: BackendKind::Reference,
        specpv: SpecPvConfig { retrieval_budget: 64, ..SpecPvConfig::default() },
        ..Config::default()
    }
}

/// Bitwise state comparison through the snapshot ABI (flat state + lazy
/// hidden rows).
fn assert_states_eq(
    be: &ReferenceBackend,
    kind: StateKind,
    size: &str,
    bucket: usize,
    a: &StateBuf,
    b: &StateBuf,
    what: &str,
) {
    let sa = be.export_state(kind, size, bucket, a).unwrap();
    let sb = be.export_state(kind, size, bucket, b).unwrap();
    assert_eq!(sa.data.len(), sb.data.len(), "{what}: state sizes diverged");
    assert_eq!(sa.extra.len(), sb.extra.len(), "{what}: lazy-row sizes diverged");
    assert!(
        sa.data.iter().zip(&sb.data).all(|(x, y)| x.to_bits() == y.to_bits()),
        "{what}: state bytes diverged"
    );
    assert!(
        sa.extra.iter().zip(&sb.extra).all(|(x, y)| x.to_bits() == y.to_bits()),
        "{what}: lazy hidden rows diverged"
    );
}

/// A full state with `chunks` committed prefill chunks (distinct token
/// content per `salt`).
fn warmed_full(be: &ReferenceBackend, chunks: usize, salt: i32) -> StateBuf {
    let c = be.consts().chunk;
    let mut st = be.alloc_state(StateKind::Full, SIZE, BUCKET).unwrap();
    for ci in 0..chunks {
        let toks: Vec<i32> = (0..c).map(|i| 65 + ((salt as usize + ci * c + i) % 26) as i32).collect();
        let pos: Vec<i32> = (0..c).map(|i| (ci * c + i) as i32).collect();
        let mask = tree::chain_mask(c, c);
        let op = PrefillOp {
            size: SIZE,
            bucket: BUCKET,
            tokens: &toks,
            pos: &pos,
            mask: &mask,
            kv_len: ci * c,
        };
        st = be.prefill(&op, st).unwrap();
    }
    st
}

/// Run `ops` batched on one state set and sequentially on a bit-identical
/// clone set; assert every resulting state matches bitwise.
fn verify_parity_case(threads: usize) {
    let be = ReferenceBackend::with_threads(threads);
    let consts = be.consts().clone();
    let t = consts.tree_t;
    let mask = tree::chain_mask(t, t);
    let zero = [0i32; 8];
    // four sessions at different committed lengths (1..=4 chunks)
    let chunks = [1usize, 2, 3, 4];
    let mut seq: Vec<StateBuf> = Vec::new();
    let mut bat: Vec<StateBuf> = Vec::new();
    for (si, &k) in chunks.iter().enumerate() {
        let st = warmed_full(&be, k, si as i32);
        let snap = be.export_state(StateKind::Full, SIZE, BUCKET, &st).unwrap();
        seq.push(st);
        bat.push(be.import_state(&snap).unwrap());
    }
    let toks: Vec<Vec<i32>> = chunks
        .iter()
        .map(|&k| (0..t as i32).map(|i| 65 + (i + k as i32) % 26).collect())
        .collect();
    let poss: Vec<Vec<i32>> = chunks
        .iter()
        .map(|&k| (0..t as i32).map(|i| (k * consts.chunk) as i32 + i).collect())
        .collect();
    let ops: Vec<VerifyOp> = (0..chunks.len())
        .map(|si| VerifyOp {
            size: SIZE,
            bucket: BUCKET,
            t,
            tokens: &toks[si],
            pos: &poss[si],
            mask: &mask,
            kv_len: chunks[si] * consts.chunk,
            prev_idx: &zero,
            n_prev: 0,
        })
        .collect();
    for (si, op) in ops.iter().enumerate() {
        let st = std::mem::replace(&mut seq[si], StateBuf::nil());
        seq[si] = be.verify_full(op, st).unwrap();
    }
    {
        let mut refs: Vec<&mut StateBuf> = bat.iter_mut().collect();
        be.verify_full_batch(&ops, &mut refs).unwrap();
    }
    for si in 0..chunks.len() {
        assert_states_eq(
            &be,
            StateKind::Full,
            SIZE,
            BUCKET,
            &seq[si],
            &bat[si],
            &format!("verify_full b=4 session {si} ({threads} threads)"),
        );
    }
}

#[test]
fn batched_verify_full_parity_mixed_kv_lens() {
    verify_parity_case(1);
    verify_parity_case(4);
}

#[test]
fn batched_prefill_parity() {
    for threads in [1usize, 4] {
        let be = ReferenceBackend::with_threads(threads);
        let c = be.consts().chunk;
        let mask = tree::chain_mask(c, c);
        let chunks = [1usize, 2, 3];
        let mut seq = Vec::new();
        let mut bat = Vec::new();
        for (si, &k) in chunks.iter().enumerate() {
            let st = warmed_full(&be, k, 7 + si as i32);
            let snap = be.export_state(StateKind::Full, SIZE, BUCKET, &st).unwrap();
            seq.push(st);
            bat.push(be.import_state(&snap).unwrap());
        }
        let toks: Vec<Vec<i32>> = chunks
            .iter()
            .map(|&k| (0..c).map(|i| 65 + ((k + i) % 26) as i32).collect())
            .collect();
        let poss: Vec<Vec<i32>> = chunks
            .iter()
            .map(|&k| (0..c).map(|i| (k * c + i) as i32).collect())
            .collect();
        let ops: Vec<PrefillOp> = (0..chunks.len())
            .map(|si| PrefillOp {
                size: SIZE,
                bucket: BUCKET,
                tokens: &toks[si],
                pos: &poss[si],
                mask: &mask,
                kv_len: chunks[si] * c,
            })
            .collect();
        for (si, op) in ops.iter().enumerate() {
            let st = std::mem::replace(&mut seq[si], StateBuf::nil());
            seq[si] = be.prefill(op, st).unwrap();
        }
        let mut refs: Vec<&mut StateBuf> = bat.iter_mut().collect();
        be.prefill_batch(&ops, &mut refs).unwrap();
        drop(refs);
        for si in 0..chunks.len() {
            assert_states_eq(
                &be,
                StateKind::Full,
                SIZE,
                BUCKET,
                &seq[si],
                &bat[si],
                &format!("prefill b=3 session {si} ({threads} threads)"),
            );
        }
    }
}

#[test]
fn batched_verify_partial_parity() {
    for threads in [1usize, 4] {
        let be = ReferenceBackend::with_threads(threads);
        let consts = be.consts().clone();
        let t = consts.tree_t;
        let p_bucket = 224usize;
        let nsel = p_bucket / consts.block;
        let n_layer = be.model(SIZE).unwrap().n_layer;
        let mask = tree::chain_mask(t, t);
        let zero = [0i32; 8];
        // gather a partial core out of a 3-chunk full state per session
        let mut seq = Vec::new();
        let mut bat = Vec::new();
        let core_len = 2 * consts.chunk; // whole blocks, < p_bucket
        for si in 0..3usize {
            let full = warmed_full(&be, 3, 11 + si as i32);
            let ncore = core_len / consts.block;
            let mut block_idx = Vec::new();
            for _l in 0..n_layer {
                for s in 0..nsel {
                    block_idx.push(s.min(ncore - 1) as i32);
                }
            }
            let gop = specpv::backend::GatherOp {
                size: SIZE,
                bucket: BUCKET,
                p_bucket,
                block_idx: &block_idx,
            };
            let pstate = be.refresh_gather(&gop, &full).unwrap();
            let snap = be.export_state(StateKind::Partial, SIZE, p_bucket, &pstate).unwrap();
            seq.push(pstate);
            bat.push(be.import_state(&snap).unwrap());
        }
        let toks: Vec<Vec<i32>> =
            (0..3).map(|si| (0..t as i32).map(|i| 66 + (i + si) % 24).collect()).collect();
        let pos: Vec<i32> = (0..t as i32).map(|i| core_len as i32 + i).collect();
        let ops: Vec<VerifyOp> = (0..3)
            .map(|si| VerifyOp {
                size: SIZE,
                bucket: p_bucket,
                t,
                tokens: &toks[si],
                pos: &pos,
                mask: &mask,
                kv_len: core_len,
                prev_idx: &zero,
                n_prev: 0,
            })
            .collect();
        for (si, op) in ops.iter().enumerate() {
            let st = std::mem::replace(&mut seq[si], StateBuf::nil());
            seq[si] = be.verify_partial(op, st).unwrap();
        }
        let mut refs: Vec<&mut StateBuf> = bat.iter_mut().collect();
        be.verify_partial_batch(&ops, &mut refs).unwrap();
        drop(refs);
        for si in 0..3 {
            assert_states_eq(
                &be,
                StateKind::Partial,
                SIZE,
                p_bucket,
                &seq[si],
                &bat[si],
                &format!("verify_partial b=3 session {si} ({threads} threads)"),
            );
        }
    }
}

#[test]
fn batched_draft_expand_parity() {
    for threads in [1usize, 4] {
        let be = ReferenceBackend::with_threads(threads);
        let consts = be.consts().clone();
        let c = consts.chunk;
        let (w, region) = (consts.draft_w, consts.draft_region);
        let h = be.model(SIZE).unwrap().d_model;
        let chunk_mask = tree::chain_mask(c, c);
        let mut seq = Vec::new();
        let mut bat = Vec::new();
        for si in 0..3usize {
            let full = warmed_full(&be, 1, 3 + si as i32);
            let mut dst = be.alloc_state(StateKind::Draft, SIZE, BUCKET).unwrap();
            let toks: Vec<i32> = (0..c).map(|i| 65 + ((si + i) % 26) as i32).collect();
            let pos: Vec<i32> = (0..c).map(|i| i as i32).collect();
            let op = DraftPrefillOp {
                size: SIZE,
                bucket: BUCKET,
                tokens: &toks,
                pos: &pos,
                mask: &chunk_mask,
                kv_len: 0,
                write_pos: 0,
            };
            dst = be.draft_prefill(&op, &full, dst).unwrap();
            let snap = be.export_state(StateKind::Draft, SIZE, BUCKET, &dst).unwrap();
            seq.push(dst);
            bat.push(be.import_state(&snap).unwrap());
        }
        let toks: Vec<Vec<i32>> =
            (0..3).map(|si| (0..w as i32).map(|i| 66 + si + i).collect()).collect();
        let feats: Vec<Vec<f32>> =
            (0..3).map(|si| vec![0.03 * (si as f32 + 1.0); w * 3 * h]).collect();
        let pos: Vec<i32> = (0..w).map(|i| (c + i) as i32).collect();
        let mut dmask = vec![0f32; w * region];
        for i in 0..w {
            for j in 0..=i {
                dmask[i * region + j] = 1.0;
            }
        }
        let ops: Vec<DraftExpandOp> = (0..3)
            .map(|si| DraftExpandOp {
                size: SIZE,
                bucket: BUCKET,
                tokens: &toks[si],
                feats: &feats[si],
                pos: &pos,
                mask: &dmask,
                kv_len: c,
                write_pos: c,
            })
            .collect();
        for (si, op) in ops.iter().enumerate() {
            let st = std::mem::replace(&mut seq[si], StateBuf::nil());
            seq[si] = be.draft_expand(op, st).unwrap();
        }
        let mut refs: Vec<&mut StateBuf> = bat.iter_mut().collect();
        be.draft_expand_batch(&ops, &mut refs).unwrap();
        drop(refs);
        for si in 0..3 {
            assert_states_eq(
                &be,
                StateKind::Draft,
                SIZE,
                BUCKET,
                &seq[si],
                &bat[si],
                &format!("draft_expand b=3 session {si} ({threads} threads)"),
            );
        }
    }
}

#[test]
fn batched_tiny_forward_parity() {
    for threads in [1usize, 4] {
        let be = ReferenceBackend::with_threads(threads);
        let consts = be.consts().clone();
        let c = consts.chunk;
        let tb = consts.tiny_bucket;
        let chunk_mask = tree::chain_mask(c, c);
        let mut seq = Vec::new();
        let mut bat = Vec::new();
        for si in 0..4usize {
            let mut st = be.alloc_state(StateKind::Tiny, "tiny", tb).unwrap();
            let toks: Vec<i32> = (0..c).map(|i| 65 + ((si + i) % 26) as i32).collect();
            let pos: Vec<i32> = (0..c).map(|i| i as i32).collect();
            let op = TinyForwardOp {
                t: c,
                tokens: &toks,
                pos: &pos,
                mask: &chunk_mask,
                kv_len: 0,
                write_pos: 0,
                last_idx: c - 1,
            };
            st = be.tiny_forward(&op, st).unwrap();
            let snap = be.export_state(StateKind::Tiny, "tiny", tb, &st).unwrap();
            seq.push(st);
            bat.push(be.import_state(&snap).unwrap());
        }
        let toks: Vec<Vec<i32>> = (0..4).map(|si| vec![70 + si as i32]).collect();
        let ops: Vec<TinyForwardOp> = (0..4)
            .map(|si| TinyForwardOp {
                t: 1,
                tokens: &toks[si],
                pos: &[c as i32],
                mask: &[1.0],
                kv_len: c,
                write_pos: c,
                last_idx: 0,
            })
            .collect();
        for (si, op) in ops.iter().enumerate() {
            let st = std::mem::replace(&mut seq[si], StateBuf::nil());
            seq[si] = be.tiny_forward(op, st).unwrap();
        }
        let mut refs: Vec<&mut StateBuf> = bat.iter_mut().collect();
        be.tiny_forward_batch(&ops, &mut refs).unwrap();
        drop(refs);
        for si in 0..4 {
            assert_states_eq(
                &be,
                StateKind::Tiny,
                "tiny",
                tb,
                &seq[si],
                &bat[si],
                &format!("tiny_forward b=4 session {si} ({threads} threads)"),
            );
        }
    }
}

/// Mixed-engine workload over the coordinator: every request's tokens
/// must equal the single-session `generate_with` bytes, the sequential
/// (batching-off) coordinator bytes, and the 1-thread backend bytes.
#[test]
fn coordinator_batched_generations_match_sequential_bytewise() {
    let prompt = corpus::continuation_prompt(21, 150);
    let toks = tokenizer::encode(&prompt);
    // two spec_full sessions guarantee fusable draft + verify geometry;
    // the rest exercise mixed-class grouping
    let kinds = [
        EngineKind::SpecFull,
        EngineKind::SpecFull,
        EngineKind::SpecPv,
        EngineKind::Autoregressive,
        EngineKind::TriForce,
    ];
    let cfg = Config { max_active: kinds.len(), ..base_cfg() };
    let run_coord = |threads: usize, batching: bool| -> (Vec<Vec<u32>>, Vec<RequestId>, u64) {
        let be = ReferenceBackend::with_threads(threads);
        let mut coord = Coordinator::new(&be, cfg.clone());
        coord.set_batching(batching);
        let ids: Vec<RequestId> = kinds
            .iter()
            .map(|&k| coord.submit(GenRequest::greedy(toks.clone(), 16), Some(k)).unwrap())
            .collect();
        coord.run_all();
        let outs = ids
            .iter()
            .map(|&id| coord.get(id).unwrap().result.as_ref().unwrap().tokens.clone())
            .collect();
        (outs, ids, coord.registry.batch_ops_fused)
    };
    let (batched4, _, fused) = run_coord(4, true);
    let (batched1, _, _) = run_coord(1, true);
    let (sequential, _, seq_fused) = run_coord(4, false);
    assert!(fused > 0, "mixed spec sessions must fuse at least some ops");
    assert_eq!(seq_fused, 0, "batching off must not fuse");
    // single-session reference for every engine
    let be = ReferenceBackend::new();
    for (i, &kind) in kinds.iter().enumerate() {
        let mut c = cfg.clone();
        c.engine = kind;
        let solo = engine::generate_with(&c, &be, &GenRequest::greedy(toks.clone(), 16))
            .unwrap()
            .tokens;
        assert_eq!(batched4[i], solo, "{kind:?}: batched coordinator diverged from solo");
        assert_eq!(batched4[i], batched1[i], "{kind:?}: thread count changed tokens");
        assert_eq!(batched4[i], sequential[i], "{kind:?}: batching changed tokens");
    }
}

/// Grouping must not reorder the scheduler-visible stream: with batching
/// on, each tick still emits at most one Step per session, rotation
/// windows stay fair, and the full event-id sequence equals the
/// batching-off coordinator's.
#[test]
fn batched_tick_preserves_rotation_and_event_order() {
    let prompt = corpus::continuation_prompt(5, 120);
    let toks = tokenizer::encode(&prompt);
    let kinds = [EngineKind::SpecFull, EngineKind::SpecFull, EngineKind::Autoregressive];
    let cfg = Config { max_active: kinds.len(), ..base_cfg() };
    let run_events = |batching: bool| -> Vec<Vec<RequestId>> {
        let be = ReferenceBackend::new();
        let mut coord = Coordinator::new(&be, cfg.clone());
        coord.set_batching(batching);
        for &k in &kinds {
            coord.submit(GenRequest::greedy(toks.clone(), 10), Some(k)).unwrap();
        }
        let mut per_tick = Vec::new();
        while !coord.idle() {
            let step_ids: Vec<RequestId> = coord
                .tick()
                .into_iter()
                .filter_map(|e| match e {
                    Event::Step { id, .. } => Some(id),
                    Event::Failed { id, error } => {
                        panic!("request {id} failed: {error}")
                    }
                    _ => None,
                })
                .collect();
            per_tick.push(step_ids);
        }
        per_tick
    };
    let batched = run_events(true);
    let sequential = run_events(false);
    assert_eq!(batched, sequential, "batching reordered the event stream");
    for (t, ids) in batched.iter().enumerate() {
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ids.len(), "tick {t}: a session stepped twice");
    }
    // no session starves: every session appears in every tick until it
    // finishes (monotone shrinking id sets)
    for w in batched.windows(2) {
        for id in &w[1] {
            assert!(
                w[0].contains(id),
                "session {id} skipped a tick then reappeared: {batched:?}"
            );
        }
    }
}
