//! Artifact-free invariant tests across the coordinator substrates:
//! deeper property sweeps and failure injection that complement the
//! per-module unit tests (these exercise cross-module behaviour).

use specpv::cache::{DraftCache, FullCache, PartialCache};
use specpv::config::{Config, Reduction, SpecPvConfig};
use specpv::metrics::{bleurt_proxy, rouge_l};
use specpv::retrieval::plan_gather;
use specpv::sampling::{argmax, pick_token, softmax, spec_accept};
use specpv::tree::{chain_mask, refresh_mask, Tree};
use specpv::util::proptest::Prop;
use specpv::util::rng::Rng;
use specpv::{corpus, tokenizer};

/// Simulated decode loop over the cache accounting: random accept
/// patterns must never violate bucket bounds or pending invariants.
#[test]
fn full_cache_random_decode_simulation() {
    Prop::new("full-cache decode sim", 300).run(|g| {
        let bucket = 1024;
        let mut c = FullCache::new(bucket);
        c.push_prefill(g.usize_in(1, 500)).unwrap();
        for _ in 0..g.usize_in(0, 60) {
            // tree verify: accept 0..=4 strictly-increasing rows < 16
            let m = g.usize_in(0, 4);
            let mut rows = vec![0usize];
            let mut last = 0;
            for _ in 0..m {
                last += g.usize_in(1, 3);
                if last < 16 {
                    rows.push(last);
                }
            }
            if c.headroom() < 16 + rows.len() {
                break;
            }
            let (kv_len, idx, n) = c.take_pending(8).unwrap();
            assert!(kv_len + n <= bucket);
            assert_eq!(idx.len(), 8);
            c.set_pending(rows, 16).unwrap();
        }
        assert!(c.effective_len() <= bucket);
    });
}

/// SpecPV mode machine: for any (budget, cap) geometry the partial cache
/// must force a refresh before the buffer or the bucket overflows.
#[test]
fn partial_cache_never_overflows() {
    Prop::new("partial-cache refresh forcing", 300).run(|g| {
        let bucket = *g.pick(&[512usize, 768, 1280]);
        let cap = g.usize_in(17, 60);
        let mut p = PartialCache::new(bucket, cap);
        p.refresh(g.usize_in(64, bucket - 64));
        let mut steps = 0;
        loop {
            if !p.fits(16, 8) {
                // refresh: everything resets
                p.refresh(g.usize_in(64, bucket - 64));
                steps += 1;
                if steps > 5 {
                    break;
                }
                continue;
            }
            // partial step: accept root + up to 3 drafted
            let m = g.usize_in(0, 3);
            let rows: Vec<usize> = (0..=m).collect();
            p.set_pending(rows, 16).unwrap();
            let (kv_len, _, n) = p.take_pending(8).unwrap();
            assert!(kv_len + n + 16 <= bucket + 16);
            for _ in 0..=m {
                p.pv_tokens.push(1);
            }
            assert!(p.pv_tokens.len() <= cap, "buffer cap violated");
        }
    });
}

#[test]
fn draft_cache_scratch_never_collides_with_chain() {
    Prop::new("draft scratch/commit discipline", 200).run(|g| {
        let mut d = DraftCache::new(4096, 32);
        d.push_prefill(g.usize_in(1, 1000)).unwrap();
        for _ in 0..g.usize_in(1, 40) {
            let chain = g.usize_in(1, 6);
            let before = d.committed;
            d.push_chain(chain).unwrap();
            assert_eq!(d.committed, before + chain);
            assert_eq!(d.scratch, 0);
            let mut used = 0;
            for _ in 0..g.usize_in(0, 3) {
                let w = g.usize_in(1, 8);
                if used + w > 32 {
                    break;
                }
                let off = d.push_scratch(w).unwrap();
                assert_eq!(off, used, "scratch must be contiguous");
                used += w;
            }
        }
    });
}

/// The verification masks must keep padded rows softmax-safe (≥1 visible
/// column) — a padded row with no visible key would produce NaNs that
/// poison the whole attention output through the flat state.
#[test]
fn masks_always_give_every_row_a_visible_column() {
    Prop::new("mask rows non-empty", 300).run(|g| {
        let mut t = Tree::new(0);
        for _ in 0..g.usize_in(0, 14) {
            let p = g.usize_in(0, t.len() - 1);
            t.add(p, g.u32() % 320, -1.0);
        }
        let t = t.prune_top(16);
        let flat = t.flatten(16);
        for i in 0..16 {
            assert!(
                (0..16).any(|j| flat.mask[i * 16 + j] > 0.5),
                "tree row {i} fully masked"
            );
        }
        let n_chain = g.usize_in(0, 40);
        let m = refresh_mask(n_chain, &flat, 64);
        for i in 0..64 {
            assert!(
                (0..64).any(|j| m[i * 64 + j] > 0.5),
                "refresh row {i} fully masked"
            );
        }
        let cm = chain_mask(g.usize_in(0, 64), 64);
        for i in 0..64 {
            assert!((0..64).any(|j| cm[i * 64 + j] > 0.5));
        }
    });
}

/// Retrieval planning: the assembled core must always contain the sink
/// block(s) and the newest (local) block — the two segments the paper
/// says are unconditionally kept.
#[test]
fn gather_plan_always_keeps_sink_and_local() {
    Prop::new("plan keeps sink+local", 300).run(|g| {
        let nb = g.usize_in(8, 128);
        let committed = g.usize_in(4 * 32, nb * 32);
        let n_layer = g.usize_in(1, 6);
        let scores: Vec<f32> =
            (0..n_layer * 3 * nb).map(|_| g.f32_in(-2.0, 2.0)).collect();
        let cfg = SpecPvConfig {
            retrieval_budget: *g.pick(&[64usize, 256, 512]),
            reduction: *g.pick(&[Reduction::Mean, Reduction::Max, Reduction::Last]),
            ..Default::default()
        };
        let nsel = (cfg.retrieval_budget / 32 + 3).min(nb);
        let plan = plan_gather(&scores, n_layer, nb, 32, committed, nsel, &cfg);
        let newest = (committed - 1) / 32;
        for ids in &plan.block_idx {
            assert_eq!(ids[0], 0, "sink block missing");
            assert!(
                ids[..plan.core_blocks].contains(&(newest as i32)),
                "newest block {newest} missing from {ids:?}"
            );
        }
        assert!(plan.core_len <= plan.core_blocks * 32);
        assert!(plan.core_len > (plan.core_blocks - 1) * 32);
    });
}

/// Speculative sampling correctness under adversarial draft dists.
#[test]
fn spec_sampling_extreme_drafts() {
    let mut rng = Rng::new(3);
    let p = vec![0.9f32, 0.05, 0.05];
    // draft almost never proposes the likely token
    let q = vec![0.01f32, 0.495, 0.495];
    let n = 40_000;
    let mut counts = [0usize; 3];
    for _ in 0..n {
        let x = specpv::sampling::sample(&q, &mut rng);
        let (_, committed) = spec_accept(&p, &q, x, &mut rng);
        counts[committed] += 1;
    }
    let f0 = counts[0] as f32 / n as f32;
    assert!((f0 - 0.9).abs() < 0.02, "committed dist broken: {f0}");
}

#[test]
fn temperature_extremes_are_safe() {
    let mut rng = Rng::new(5);
    let logits = vec![1e4f32, -1e4, 0.0];
    // huge logits at tiny temperature must not NaN
    let p = softmax(&logits, 1e-8);
    assert!((p[0] - 1.0).abs() < 1e-5);
    assert_eq!(pick_token(&logits, 0.0, &mut rng), argmax(&logits) as u32);
    let p2 = softmax(&logits, 1e6);
    assert!(p2.iter().all(|x| x.is_finite()));
}

/// Metrics sanity over generated corpora (symmetric, bounded, identical
/// text maximal).
#[test]
fn metrics_properties() {
    Prop::new("metrics bounded+symmetricish", 60).run(|g| {
        let a = corpus::novel_text(g.u64(), 300 + g.usize_in(0, 300));
        let b = corpus::meeting_text(g.u64(), 300 + g.usize_in(0, 300));
        for m in [rouge_l(&a, &b), bleurt_proxy(&a, &b)] {
            assert!((0.0..=100.0001).contains(&m));
        }
        assert!((bleurt_proxy(&a, &a) - 100.0).abs() < 1e-6);
        assert!((rouge_l(&a, &a) - 100.0).abs() < 1e-6);
        // bleurt proxy is symmetric by construction
        assert!((bleurt_proxy(&a, &b) - bleurt_proxy(&b, &a)).abs() < 1e-6);
    });
}

/// Tokenizer/corpus cross-checks at scale.
#[test]
fn corpus_tokens_roundtrip_everywhere() {
    Prop::new("corpus↔tokens roundtrip", 40).run(|g| {
        let n = 200 + g.usize_in(0, 2000);
        let t = match g.usize_in(0, 3) {
            0 => corpus::novel_text(g.u64(), n),
            1 => corpus::report_text(g.u64(), n),
            2 => corpus::meeting_text(g.u64(), n),
            _ => corpus::needle_qa(g.u64(), n, 4).context,
        };
        let ids = tokenizer::encode(&t);
        assert_eq!(tokenizer::decode(&ids), t);
        assert!(ids.iter().all(|&i| i < 256));
    });
}

/// Config file parsing failure injection.
#[test]
fn config_failure_injection() {
    let dir = std::env::temp_dir().join("specpv_cfg_tests");
    std::fs::create_dir_all(&dir).unwrap();
    // valid file
    let good = dir.join("good.conf");
    std::fs::write(&good, "engine = spec_pv\nretrieval_budget = 256\n# c\n").unwrap();
    let c = Config::from_file(&good).unwrap();
    assert_eq!(c.specpv.retrieval_budget, 256);
    // malformed lines
    for bad in ["novalue\n", "engine = warp9\n", "retrieval_budget = many\n"] {
        let p = dir.join("bad.conf");
        std::fs::write(&p, bad).unwrap();
        assert!(Config::from_file(&p).is_err(), "accepted {bad:?}");
    }
    assert!(Config::from_file(&dir.join("missing.conf")).is_err());
}

/// Greedy accept on a chain tree == longest matching prefix.
#[test]
fn chain_acceptance_is_prefix_match() {
    Prop::new("chain accept == prefix", 200).run(|g| {
        let gamma = g.usize_in(1, 6);
        let mut t = Tree::new(10);
        let mut parent = 0;
        let chain: Vec<u32> = (0..gamma).map(|_| g.u32() % 50).collect();
        for &c in &chain {
            parent = t.add(parent, c, -0.1);
        }
        // picks: target wants chain[i] at node i with prob; flip some
        let mut picks = vec![0u32; t.len()];
        let mut expected = 0;
        let mut broken = false;
        for i in 0..gamma {
            if !broken && g.f32_in(0.0, 1.0) < 0.7 {
                picks[i] = chain[i];
                expected += 1;
            } else {
                picks[i] = 333; // not in vocab of children
                broken = true;
            }
        }
        picks[gamma] = 99;
        let (path, _) = t.greedy_accept(&picks);
        assert_eq!(path.len(), expected);
    });
}
