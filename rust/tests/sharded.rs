//! Integration tests of the sharded serving subsystem (DESIGN.md §14):
//!   * a 256-client streaming soak across 2 shards — mixed engines,
//!     mid-stream cancels, globally unique wire ids, zero lost or
//!     duplicated lines, aggregated admin counters, clean shutdown
//!   * the `shards = 1` compatibility pin — the server's final line
//!     matches a direct coordinator run key-for-key and byte-for-byte,
//!     with the same id sequence
//!   * prefix-affinity routing on the reference backend — deterministic
//!     home shard, repeat-prefix generations hit the home shard's prefix
//!     cache (a repeated session start materializes zero new pages),
//!     and a forced re-route misses the cache but stays byte-identical
//!   * graceful drain — a `shutdown` op mid-generation streams a
//!     `{"draining":true,"done":false}` marker, the in-flight request
//!     still gets its full final line, and late ops are refused

use std::collections::HashSet;
use std::net::TcpListener;
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

use specpv::backend::reference::ReferenceBackend;
use specpv::config::{BackendKind, Config, EngineKind};
use specpv::coordinator::Coordinator;
use specpv::engine::scripted::ScriptedFactory;
use specpv::engine::{self, GenRequest};
use specpv::json::Json;
use specpv::kvstore::{KvCtx, KvStore};
use specpv::serve::router::Router;
use specpv::serve::serve_scripted;
use specpv::server::{serve_on, Client};
use specpv::{corpus, tokenizer};

const SOAK_CLIENTS: usize = 256;

#[test]
fn soak_256_streaming_clients_across_two_shards() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = Config { max_active: 8, shards: 2, ..Config::default() };
    let factory = ScriptedFactory {
        tokens_per_step: 2,
        step_micros: 200,
        ..ScriptedFactory::default()
    };
    let server = thread::spawn(move || serve_scripted(listener, cfg, factory));

    // wire ids must be globally unique across shards
    let ids = Arc::new(Mutex::new(HashSet::<u64>::new()));
    let mut clients = Vec::new();
    for c in 0..SOAK_CLIENTS {
        let addr = addr.clone();
        let ids = ids.clone();
        clients.push(thread::spawn(move || {
            let engines = ["spec_pv", "ar", "triforce", "spec_full", "tokenswift"];
            let engine = engines[c % engines.len()];
            let mut cl = Client::connect(&addr).unwrap();
            let prompt = format!("soak client {c} prompt payload");
            if c % 16 == 0 {
                // cancel mid-stream: a generation far too long to finish,
                // cancelled after two delta lines
                cl.send(
                    Json::obj()
                        .set("op", "generate")
                        .set("prompt", prompt.as_str())
                        .set("max_new", 4096usize)
                        .set("engine", engine)
                        .set("stream", true),
                )
                .unwrap();
                let ack = cl.recv().unwrap();
                assert_eq!(
                    ack.get("queued").and_then(|x| x.as_bool()),
                    Some(true),
                    "{ack:?}"
                );
                let id = ack.get("id").and_then(|x| x.as_i64()).unwrap();
                assert!(
                    ids.lock().unwrap().insert(id as u64),
                    "duplicate wire id {id}"
                );
                let mut deltas = 0usize;
                let mut cancel_sent = false;
                let fin = loop {
                    let j = cl.recv().unwrap();
                    if j.get("done").and_then(|x| x.as_bool()) == Some(true) {
                        break j;
                    }
                    if j.get("delta").is_some() {
                        deltas += 1;
                        if deltas == 2 && !cancel_sent {
                            cl.send(Json::obj().set("op", "cancel").set("id", id))
                                .unwrap();
                            cancel_sent = true;
                        }
                    }
                };
                assert_eq!(
                    fin.get("cancelled").and_then(|x| x.as_bool()),
                    Some(true),
                    "not cancelled mid-flight: {fin:?}"
                );
                // the cancel ack arrives strictly after the final line
                let cancel_ack = cl.recv().unwrap();
                assert_eq!(
                    cancel_ack.get("cancelled").and_then(|x| x.as_bool()),
                    Some(true),
                    "{cancel_ack:?}"
                );
            } else {
                let (steps, fin) = cl.generate_stream(&prompt, 24, engine).unwrap();
                assert_eq!(
                    fin.get("ok").and_then(|x| x.as_bool()),
                    Some(true),
                    "{fin:?}"
                );
                assert_eq!(fin.get("tokens").and_then(|x| x.as_usize()), Some(24));
                let id = fin.get("id").and_then(|x| x.as_i64()).unwrap();
                assert!(
                    ids.lock().unwrap().insert(id as u64),
                    "duplicate wire id {id}"
                );
                assert_eq!(
                    steps[0].get("id").and_then(|x| x.as_i64()),
                    Some(id),
                    "queued ack id mismatch: {steps:?}"
                );
                // zero lost or duplicated lines: the concatenated deltas
                // reproduce the final text exactly
                let delta_text: String = steps
                    .iter()
                    .filter_map(|j| j.get("delta").and_then(|x| x.as_str()))
                    .collect();
                assert_eq!(
                    Some(delta_text.as_str()),
                    fin.get("text").and_then(|x| x.as_str()),
                    "lost/dup stream lines for client {c}"
                );
            }
        }));
    }
    for t in clients {
        t.join().unwrap();
    }
    assert_eq!(ids.lock().unwrap().len(), SOAK_CLIENTS);

    let mut admin = Client::connect(&addr).unwrap();
    let s = admin.admin("shards").unwrap();
    assert_eq!(s.get("ok").and_then(|x| x.as_bool()), Some(true), "{s:?}");
    assert_eq!(s.get("cmd").and_then(|x| x.as_str()), Some("shards"));
    assert_eq!(s.get("shards").and_then(|x| x.as_usize()), Some(2));
    let per = match s.get("per_shard") {
        Some(Json::Arr(v)) => v.clone(),
        other => panic!("per_shard missing: {other:?}"),
    };
    assert_eq!(per.len(), 2);
    let placed: usize = per
        .iter()
        .map(|p| p.get("placed").and_then(|x| x.as_usize()).unwrap())
        .sum();
    assert_eq!(placed, SOAK_CLIENTS, "every session placed exactly once");
    for p in &per {
        assert_eq!(p.get("load").and_then(|x| x.as_usize()), Some(0), "{p:?}");
        assert!(p.get("placed").and_then(|x| x.as_usize()).unwrap() > 0, "{p:?}");
    }

    // merged metrics: counters sum across both shards
    let m = admin.admin("metrics").unwrap();
    assert_eq!(m.get("ok").and_then(|x| x.as_bool()), Some(true), "{m:?}");
    assert_eq!(
        m.get("completed").and_then(|x| x.as_i64()),
        Some((SOAK_CLIENTS - SOAK_CLIENTS / 16) as i64),
        "{m:?}"
    );
    assert_eq!(
        m.get("cancelled").and_then(|x| x.as_i64()),
        Some((SOAK_CLIENTS / 16) as i64),
        "{m:?}"
    );
    admin.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

/// Drive one request through a bare coordinator to completion.
fn direct_run(coord: &mut Coordinator<'_>, req: GenRequest) -> (String, usize) {
    let id = coord.submit(req, Some(EngineKind::SpecPv)).unwrap();
    while !coord.idle() {
        coord.tick();
    }
    let tr = coord.get(id).unwrap();
    let r = tr.result.as_ref().expect("request must complete");
    (r.text(), r.tokens.len())
}

#[test]
fn single_shard_is_byte_identical_to_direct_coordinator_run() {
    let factory = ScriptedFactory { tokens_per_step: 3, ..ScriptedFactory::default() };
    let cfg = Config { max_active: 2, ..Config::default() };

    let mut coord = Coordinator::with_factory(cfg.clone(), Box::new(factory.clone()));
    let req = GenRequest::greedy(tokenizer::encode("byte identity pin"), 17);
    let (want_text, want_tokens) = direct_run(&mut coord, req);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let coord = Coordinator::with_factory(cfg, Box::new(factory));
    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr).unwrap();
        let fin = c.generate("byte identity pin", 17, "spec_pv").unwrap();
        assert_eq!(fin.get("ok").and_then(|x| x.as_bool()), Some(true), "{fin:?}");
        assert_eq!(fin.get("text").and_then(|x| x.as_str()), Some(want_text.as_str()));
        assert_eq!(fin.get("tokens").and_then(|x| x.as_usize()), Some(want_tokens));
        // wire ids are assigned in parse order from 0, exactly like the
        // old per-coordinator request ids
        assert_eq!(fin.get("id").and_then(|x| x.as_i64()), Some(0));
        // the final-line key set is the frozen wire contract
        let keys: Vec<&str> =
            fin.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
        assert_eq!(
            keys,
            [
                "done", "id", "latency_s", "modes", "ok", "steps", "tau", "text",
                "tok_per_s", "tokens", "ttft_s"
            ],
            "final line keys drifted"
        );
        let fin2 = c.generate("byte identity pin", 17, "spec_pv").unwrap();
        assert_eq!(fin2.get("id").and_then(|x| x.as_i64()), Some(1));
        assert_eq!(
            fin2.get("text").and_then(|x| x.as_str()),
            Some(want_text.as_str())
        );
        c.shutdown().unwrap();
    });
    serve_on(listener, coord).unwrap();
    client.join().unwrap();
}

#[test]
fn prefix_affinity_hits_home_cache_and_reroute_stays_byte_identical() {
    // routing is deterministic across router instances and sticky under
    // shared prefixes
    let prompt_text = corpus::continuation_prompt(11, 1200);
    let mut prompt = tokenizer::encode(&prompt_text);
    prompt.truncate(256);
    let home = Router::new(2, 2.0).home(&prompt);
    assert_eq!(home, Router::new(2, 2.0).home(&prompt), "home must be stable");
    let mut extended = prompt.clone();
    extended.extend_from_slice(&[7, 8, 9]);
    assert_eq!(
        home,
        Router::new(2, 2.0).home(&extended),
        "a shared first chunk must share the home shard"
    );

    let cfg = Config {
        backend: BackendKind::Reference,
        engine: EngineKind::Autoregressive,
        ..Config::default()
    };
    let req = GenRequest::greedy(prompt.clone(), 8);

    // PR 6 gate, per shard-private cache: a repeated session start on the
    // same store materializes zero new pages
    let be = ReferenceBackend::new();
    let store = KvStore::new(64 << 20);
    let kv = KvCtx::with_prefix(store.clone());
    drop(engine::build(&cfg).start(&be, &req, &kv).unwrap());
    let allocs_before = store.pool().stats().page_allocs;
    drop(engine::build(&cfg).start(&be, &req, &kv).unwrap());
    assert_eq!(
        store.pool().stats().page_allocs - allocs_before,
        0,
        "repeat-prefix start must allocate zero new pages"
    );

    // two "shards": independent backends + coordinators, each with its
    // own prefix cache, like the serving subsystem builds them
    let be_home = ReferenceBackend::new();
    let be_other = ReferenceBackend::new();
    let mut coord_home = Coordinator::new(&be_home, cfg.clone());
    let mut coord_other = Coordinator::new(&be_other, cfg.clone());

    let run = |coord: &mut Coordinator<'_>| -> Vec<u32> {
        let id = coord.submit(GenRequest::greedy(prompt.clone(), 8), None).unwrap();
        while !coord.idle() {
            coord.tick();
        }
        coord.get(id).unwrap().result.as_ref().unwrap().tokens.clone()
    };

    let first = run(&mut coord_home);
    let hits_before = coord_home.kv_stats().prefix.hits;
    let second = run(&mut coord_home);
    assert_eq!(first, second, "home-shard repeat diverged");
    assert!(
        coord_home.kv_stats().prefix.hits > hits_before,
        "repeat prefix missed the home shard's cache"
    );

    // a forced re-route (imbalance spill) lands on a cold cache: misses,
    // but the output is byte-identical
    let third = run(&mut coord_other);
    assert_eq!(coord_other.kv_stats().prefix.hits, 0, "cold shard cannot hit");
    assert!(coord_other.kv_stats().prefix.misses > 0);
    assert_eq!(third, first, "re-routed generation must be byte-identical");
}

#[test]
fn shutdown_drains_in_flight_streams_with_marker_and_final_line() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = Config { max_active: 2, shards: 2, ..Config::default() };
    let factory = ScriptedFactory {
        tokens_per_step: 2,
        step_micros: 500,
        ..ScriptedFactory::default()
    };
    let server = thread::spawn(move || serve_scripted(listener, cfg, factory));

    // streamer tells the controller when its generation is in flight
    let (started_tx, started_rx) = mpsc::channel::<()>();
    let a1 = addr.clone();
    let streamer = thread::spawn(move || {
        let mut c = Client::connect(&a1).unwrap();
        c.send(
            Json::obj()
                .set("op", "generate")
                .set("prompt", "drain me gently")
                .set("max_new", 400usize)
                .set("stream", true),
        )
        .unwrap();
        let ack = c.recv().unwrap();
        assert_eq!(ack.get("queued").and_then(|x| x.as_bool()), Some(true), "{ack:?}");
        let mut signalled = false;
        let mut saw_marker = false;
        let fin = loop {
            let j = c.recv().unwrap();
            if j.get("done").and_then(|x| x.as_bool()) == Some(true) {
                break j;
            }
            if j.get("draining").and_then(|x| x.as_bool()) == Some(true) {
                saw_marker = true;
            }
            if j.get("delta").is_some() && !signalled {
                started_tx.send(()).unwrap();
                signalled = true;
            }
        };
        assert!(saw_marker, "no draining marker before the final line");
        // drain runs the request dry — full output, not a cancellation
        assert_eq!(fin.get("ok").and_then(|x| x.as_bool()), Some(true), "{fin:?}");
        assert_eq!(fin.get("tokens").and_then(|x| x.as_usize()), Some(400));
        assert!(fin.get("cancelled").is_none(), "drain must not cancel: {fin:?}");
    });

    started_rx.recv().unwrap();
    let mut ctl = Client::connect(&addr).unwrap();
    ctl.shutdown().unwrap();
    // post-shutdown ops on a still-open connection are refused
    let late = ctl.generate("too late", 4, "spec_pv").unwrap();
    assert_eq!(late.get("ok").and_then(|x| x.as_bool()), Some(false), "{late:?}");
    assert!(
        late.get("error")
            .and_then(|x| x.as_str())
            .is_some_and(|e| e.contains("shutting down")),
        "{late:?}"
    );

    streamer.join().unwrap();
    server.join().unwrap().unwrap();
}
