//! Adaptive speculation policy tests (DESIGN.md §16) — the controller's
//! hard invariants, the losslessness contract, and the coordinator-level
//! policy loop over scripted acceptance streams:
//!   * the depth controller never leaves `[draft_min, draft_max]`;
//!   * the controller is byte-deterministic given the same observation
//!     stream;
//!   * `policy=adaptive` on a losslessness-contracted engine (spec_full,
//!     triforce, tokenswift) produces output byte-identical to
//!     `policy=off` on the real reference backend;
//!   * the coordinator's policy tick grows depth, fires drift-triggered
//!     refreshes (adaptive only) and publishes the per-engine counters;
//!   * `engine=auto` picks the engine from the prompt length.

use specpv::backend::reference::ReferenceBackend;
use specpv::config::{
    BackendKind, Config, EngineKind, PolicyConfig, PolicyMode, SpecPvConfig,
};
use specpv::coordinator::{Coordinator, RequestId, SubmitOpts};
use specpv::corpus;
use specpv::engine::scripted::{ScriptedFactory, SpecSim};
use specpv::engine::GenRequest;
use specpv::policy::{PolicyState, SpecObservation};
use specpv::tokenizer;
use specpv::util::proptest::Prop;

/// Aggressive adaptive knobs: adjust every round so short runs exercise
/// many directives.
fn adaptive(mode: PolicyMode) -> PolicyConfig {
    PolicyConfig {
        mode,
        draft_min: 1,
        draft_max: 6,
        alpha: 0.5,
        grow: 0.8,
        shrink: 0.35,
        adjust_every: 1,
        drift_threshold: 1.5,
        ..PolicyConfig::default()
    }
}

fn spec_coord(sim: SpecSim, policy: PolicyConfig) -> Coordinator<'static> {
    let cfg = Config { engine: EngineKind::SpecPv, max_active: 4, policy, ..Config::default() };
    let factory = ScriptedFactory { spec: Some(sim), ..ScriptedFactory::default() };
    Coordinator::with_factory(cfg, Box::new(factory))
}

fn run_to_done(c: &mut Coordinator<'static>, id: RequestId) -> Vec<u32> {
    while !c.idle() {
        c.tick();
    }
    c.get(id).unwrap().result.as_ref().expect("completed").tokens.clone()
}

#[test]
fn depth_controller_never_leaves_bounds() {
    Prop::new("policy depth stays in [draft_min, draft_max]", 200).run(|g| {
        let lo = g.usize_in(1, 4);
        let cfg = PolicyConfig {
            mode: PolicyMode::Adaptive,
            draft_min: lo,
            draft_max: lo + g.usize_in(0, 6),
            alpha: g.f32_in(0.05, 0.95) as f64,
            grow: g.f32_in(0.0, 1.0) as f64,
            shrink: g.f32_in(0.0, 1.0) as f64,
            adjust_every: g.usize_in(1, 4),
            drift_threshold: g.f32_in(0.2, 3.0) as f64,
            ..PolicyConfig::default()
        };
        let mut st = PolicyState::default();
        // cumulative observation stream with random per-tick deltas
        let mut obs = SpecObservation { depth: g.usize_in(1, 10), ..Default::default() };
        for _ in 0..g.usize_in(1, 60) {
            let rounds = g.usize_in(0, 3) as u64;
            let prop = rounds * g.usize_in(1, 8) as u64;
            obs.verify_steps += rounds;
            obs.proposed += prop;
            obs.committed += if prop == 0 { 0 } else { g.usize_in(0, prop as usize) as u64 };
            obs.partial_steps += g.usize_in(0, rounds as usize) as u64;
            obs.refresh_steps += g.usize_in(0, 1) as u64;
            obs.full_steps = obs.refresh_steps;
            obs.pv_len = g.usize_in(0, 12);
            obs.context_len += rounds as usize;
            let up = st.update(&cfg, obs);
            assert!(
                st.depth >= cfg.draft_min && st.depth <= cfg.draft_max,
                "depth {} escaped [{}, {}]",
                st.depth,
                cfg.draft_min,
                cfg.draft_max
            );
            if let Some(d) = up.directive.draft_depth {
                assert!(d >= cfg.draft_min && d <= cfg.draft_max);
            }
        }
    });
}

#[test]
fn controller_is_byte_deterministic() {
    Prop::new("same observation stream, same directive stream", 100).run(|g| {
        let cfg = adaptive(PolicyMode::Adaptive);
        // pre-generate a random cumulative stream, then fold it twice
        let mut stream = Vec::new();
        let mut obs = SpecObservation { depth: 4, ..Default::default() };
        for _ in 0..g.usize_in(1, 40) {
            let rounds = g.usize_in(1, 2) as u64;
            let prop = rounds * g.usize_in(1, 6) as u64;
            obs.verify_steps += rounds;
            obs.proposed += prop;
            obs.committed += g.usize_in(0, prop as usize) as u64;
            obs.partial_steps += rounds;
            obs.pv_len += rounds as usize;
            stream.push(obs);
        }
        let (mut a, mut b) = (PolicyState::default(), PolicyState::default());
        for o in &stream {
            let ua = a.update(&cfg, *o);
            let ub = b.update(&cfg, *o);
            assert_eq!(ua.directive, ub.directive);
            assert_eq!(a, b, "states diverged on identical input");
        }
    });
}

/// The losslessness contract (ISSUE criterion): on the real reference
/// backend, a losslessness-contracted engine under `policy=adaptive`
/// emits output byte-identical to `policy=off`.
#[test]
fn lossless_engines_identical_under_adaptive_policy() {
    let cfg_base = Config {
        backend: BackendKind::Reference,
        // small partial core so SpecPV-style geometry stays cheap
        specpv: SpecPvConfig { retrieval_budget: 64, ..SpecPvConfig::default() },
        max_active: 1,
        ..Config::default()
    };
    let prompt = tokenizer::encode(&corpus::continuation_prompt(0, 150));
    for kind in [EngineKind::SpecFull, EngineKind::TriForce, EngineKind::TokenSwift] {
        let mut runs = Vec::new();
        for mode in [PolicyMode::Off, PolicyMode::Adaptive] {
            let be = ReferenceBackend::new();
            let cfg = Config { policy: adaptive(mode), ..cfg_base.clone() };
            let mut coord = Coordinator::new(&be, cfg);
            let id = coord
                .submit(GenRequest::greedy(prompt.clone(), 24), Some(kind))
                .unwrap();
            runs.push(run_to_done(&mut coord, id));
        }
        assert!(!runs[0].is_empty(), "{kind:?} produced nothing");
        assert_eq!(
            runs[0], runs[1],
            "{kind:?}: policy=adaptive diverged from policy=off"
        );
    }
}

/// High steady acceptance grows the draft depth; the registry publishes
/// the per-engine speculation counters and the policy gauges.
#[test]
fn scripted_adaptive_grows_depth_and_reports_counters() {
    let sim = SpecSim { accepts: vec![6], depth: 2, ..SpecSim::default() };
    let mut c = spec_coord(sim, adaptive(PolicyMode::Adaptive));
    let id = c.submit(GenRequest::greedy(vec![1, 2], 120), None).unwrap();
    let tokens = run_to_done(&mut c, id);
    assert_eq!(tokens.len(), 120);
    assert!(
        c.registry.policy_depth_changes > 0,
        "depth never adapted: {}",
        c.registry.summary()
    );
    let spec = c
        .registry
        .spec
        .get(&EngineKind::SpecPv.to_string())
        .expect("per-engine spec counters");
    assert!(spec.proposed > 0 && spec.committed > 0);
    assert!(spec.committed <= spec.proposed);
    assert!(spec.tau_mean() > 0.0);
    let s = c.registry.summary();
    assert!(s.contains("policy=adaptive"), "{s}");
    assert!(s.contains("policy_depth_changes="), "{s}");
}

/// Decaying acceptance accumulates drift and forces a refresh under
/// `policy=adaptive`; under `policy=fixed` the same stream forces none.
#[test]
fn drift_triggered_refresh_fires_only_in_adaptive() {
    let sim = SpecSim {
        accepts: vec![4],
        depth: 4,
        decay_every: 1,
        refresh_every: 0,
        ..SpecSim::default()
    };
    let mut a = spec_coord(sim.clone(), adaptive(PolicyMode::Adaptive));
    let id = a.submit(GenRequest::greedy(vec![1], 100), None).unwrap();
    run_to_done(&mut a, id);
    assert!(
        a.registry.policy_refreshes > 0,
        "drift never forced a refresh: {}",
        a.registry.summary()
    );

    let mut f = spec_coord(sim, adaptive(PolicyMode::Fixed));
    let id = f.submit(GenRequest::greedy(vec![1], 100), None).unwrap();
    run_to_done(&mut f, id);
    assert_eq!(f.registry.policy_refreshes, 0, "{}", f.registry.summary());
}

/// The scripted stream is position-indexed, so policy decisions change
/// costs and counters but never bytes — pinned through the whole
/// coordinator loop.
#[test]
fn scripted_output_identical_adaptive_vs_off() {
    let sim = SpecSim {
        accepts: vec![5],
        depth: 3,
        decay_every: 2,
        refresh_every: 8,
        ..SpecSim::default()
    };
    let mut outs = Vec::new();
    for mode in [PolicyMode::Off, PolicyMode::Adaptive] {
        let mut c = spec_coord(sim.clone(), adaptive(mode));
        let id = c.submit(GenRequest::greedy(vec![7], 90), None).unwrap();
        outs.push(run_to_done(&mut c, id));
    }
    assert_eq!(outs[0], outs[1]);
    assert_eq!(outs[0].len(), 90);
}

/// `engine=auto`: short prompts stay autoregressive, mid-length prompts
/// take the tree engine, long prompts take SpecPV — and the registry
/// counts each auto selection.
#[test]
fn engine_auto_selects_by_prompt_length() {
    let cfg = Config {
        engine: EngineKind::Autoregressive,
        engine_auto: true,
        max_active: 4,
        policy: adaptive(PolicyMode::Adaptive),
        ..Config::default()
    };
    let factory = ScriptedFactory::default();
    let mut c = Coordinator::with_factory(cfg, Box::new(factory));
    // defaults: auto_short = 64, auto_long = 640
    let cases = [
        (10usize, EngineKind::Autoregressive),
        (100, EngineKind::TriForce),
        (700, EngineKind::SpecPv),
    ];
    let mut ids = Vec::new();
    for (len, _) in cases {
        let req = GenRequest::greedy(vec![3; len], 8);
        ids.push(c.submit_opts(req, SubmitOpts { auto: true, ..SubmitOpts::default() }).unwrap());
    }
    while !c.idle() {
        c.tick();
    }
    for (&id, (len, want)) in ids.iter().zip(cases) {
        let tr = c.get(id).unwrap();
        assert_eq!(tr.engine, want, "prompt_len={len} routed to {:?}", tr.engine);
        assert_eq!(tr.result.as_ref().unwrap().tokens.len(), 8);
    }
    let total: u64 = c.registry.auto_selected.values().sum();
    assert_eq!(total, 3, "{:?}", c.registry.auto_selected);
    assert_eq!(c.registry.auto_selected.len(), 3);
    let s = c.registry.summary();
    assert!(s.contains("auto_"), "{s}");

    // an explicit engine override bypasses auto-selection
    let req = GenRequest::greedy(vec![3; 700], 4);
    let id = c.submit(req, Some(EngineKind::Autoregressive)).unwrap();
    while !c.idle() {
        c.tick();
    }
    assert_eq!(c.get(id).unwrap().engine, EngineKind::Autoregressive);
    let total: u64 = c.registry.auto_selected.values().sum();
    assert_eq!(total, 3, "explicit engine must not count as auto");
}
