//! Loopback integration tests of the concurrent TCP server over scripted
//! sessions — no artifacts needed. The server (device loop) runs on the
//! test thread via `serve_on`; clients run in spawned threads. Covered:
//!   * two concurrent clients, one streaming, both complete correctly
//!     (results keyed by request id — the old submit/step front-of-queue
//!     race would hand one client the other's completion)
//!   * streaming emits a queued ack + per-step delta lines + a final line
//!     whose text equals the concatenated deltas
//!   * mid-generation cancellation over the wire keeps the partial text
//!   * metrics op exposes queue/active gauges and TTFT percentiles

use std::net::TcpListener;
use std::thread;

use specpv::config::Config;
use specpv::coordinator::Coordinator;
use specpv::engine::scripted::ScriptedFactory;
use specpv::json::Json;
use specpv::server::{serve_on, Client};

fn scripted_coordinator(
    max_active: usize,
    tokens_per_step: usize,
    step_micros: u64,
) -> Coordinator<'static> {
    let cfg = Config { max_active, ..Config::default() };
    let factory = ScriptedFactory {
        tokens_per_step,
        step_micros,
        ..ScriptedFactory::default()
    };
    Coordinator::with_factory(cfg, Box::new(factory))
}

#[test]
fn two_concurrent_clients_one_streaming() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let coord = scripted_coordinator(4, 2, 0);

    let a1 = addr.clone();
    let t1 = thread::spawn(move || {
        let mut c = Client::connect(&a1).unwrap();
        let r = c.generate("hello from client one", 24, "spec_pv").unwrap();
        assert_eq!(r.get("ok").and_then(|x| x.as_bool()), Some(true), "{r:?}");
        assert_eq!(r.get("done").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(r.get("tokens").and_then(|x| x.as_usize()), Some(24));
        assert!(r.get("text").and_then(|x| x.as_str()).is_some());
        assert!(r.get("id").is_some());
        assert!(r.get("ttft_s").is_some());
    });
    let a2 = addr.clone();
    let t2 = thread::spawn(move || {
        let mut c = Client::connect(&a2).unwrap();
        let (steps, fin) =
            c.generate_stream("stream me please", 24, "spec_full").unwrap();
        // first line is the queued ack with the request id
        assert_eq!(steps[0].get("queued").and_then(|x| x.as_bool()), Some(true));
        assert!(steps[0].get("id").is_some());
        // at least one incremental delta line, then the final line
        let deltas: Vec<&Json> =
            steps.iter().filter(|j| j.get("delta").is_some()).collect();
        assert!(!deltas.is_empty(), "no stream deltas: {steps:?}");
        let delta_text: String = deltas
            .iter()
            .map(|j| j.get("delta").and_then(|x| x.as_str()).unwrap_or(""))
            .collect();
        assert_eq!(fin.get("ok").and_then(|x| x.as_bool()), Some(true), "{fin:?}");
        assert_eq!(fin.get("tokens").and_then(|x| x.as_usize()), Some(24));
        // the concatenated deltas reproduce the final text exactly
        assert_eq!(
            Some(delta_text.as_str()),
            fin.get("text").and_then(|x| x.as_str())
        );
    });
    let closer = thread::spawn(move || {
        t1.join().unwrap();
        t2.join().unwrap();
        let mut c = Client::connect(&addr).unwrap();
        let m = c.metrics().unwrap();
        assert_eq!(m.get("ok").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(m.get("completed").and_then(|x| x.as_i64()), Some(2), "{m:?}");
        assert_eq!(m.get("queue_depth").and_then(|x| x.as_i64()), Some(0));
        assert_eq!(m.get("active").and_then(|x| x.as_i64()), Some(0));
        assert!(m.get("ttft_p50_s").is_some());
        c.shutdown().unwrap();
    });

    serve_on(listener, coord).unwrap();
    closer.join().unwrap();
}

#[test]
fn cancel_streaming_request_mid_generation() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // 1 token/step with 300µs simulated device latency → a 1024-token
    // generation takes ~0.3s, so the cancel lands mid-flight
    let coord = scripted_coordinator(2, 1, 300);

    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr).unwrap();
        c.send(
            Json::obj()
                .set("op", "generate")
                .set("prompt", "cancel me")
                .set("max_new", 1024)
                .set("stream", true),
        )
        .unwrap();
        let ack = c.recv().unwrap();
        assert_eq!(ack.get("queued").and_then(|x| x.as_bool()), Some(true), "{ack:?}");
        let id = ack.get("id").and_then(|x| x.as_i64()).unwrap();

        let mut deltas = 0usize;
        let mut cancel_sent = false;
        let fin = loop {
            let j = c.recv().unwrap();
            if j.get("done").and_then(|x| x.as_bool()) == Some(true) {
                break j;
            }
            if j.get("delta").is_some() {
                deltas += 1;
                if deltas == 2 && !cancel_sent {
                    c.send(Json::obj().set("op", "cancel").set("id", id)).unwrap();
                    cancel_sent = true;
                }
            }
        };
        assert_eq!(
            fin.get("cancelled").and_then(|x| x.as_bool()),
            Some(true),
            "generation was not cancelled mid-flight: {fin:?}"
        );
        let text = fin.get("text").and_then(|x| x.as_str()).unwrap();
        assert!(!text.is_empty() && text.len() < 1024, "partial text: {text:?}");
        // the cancel op's own ack arrives after the final line
        let cancel_ack = c.recv().unwrap();
        assert_eq!(
            cancel_ack.get("cancelled").and_then(|x| x.as_bool()),
            Some(true),
            "{cancel_ack:?}"
        );
        c.shutdown().unwrap();
    });

    serve_on(listener, coord).unwrap();
    client.join().unwrap();
}

#[test]
fn cache_op_reports_kv_state_manager_stats() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // scripted coordinator: no prefix store, empty pool — the op must
    // still answer with zeroed stats rather than an error
    let coord = scripted_coordinator(2, 2, 0);

    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr).unwrap();
        let r = c.generate("warm the scheduler", 8, "spec_pv").unwrap();
        assert_eq!(r.get("ok").and_then(|x| x.as_bool()), Some(true), "{r:?}");
        let s = c.cache().unwrap();
        assert_eq!(s.get("ok").and_then(|x| x.as_bool()), Some(true), "{s:?}");
        for key in [
            "prefix_entries",
            "prefix_bytes",
            "prefix_hits",
            "prefix_misses",
            "kv_resident_bytes",
            "kv_budget_bytes",
            "swapped",
            "swap_outs",
            "swap_ins",
        ] {
            assert!(s.get(key).is_some(), "missing {key}: {s:?}");
        }
        assert_eq!(s.get("kv_resident_bytes").and_then(|x| x.as_i64()), Some(0));
        assert_eq!(s.get("swapped").and_then(|x| x.as_i64()), Some(0));
        // metrics op carries the same gauges for dashboards
        let m = c.metrics().unwrap();
        assert!(m.get("kv_resident_bytes").is_some(), "{m:?}");
        assert!(m.get("swap_outs").is_some(), "{m:?}");
        assert!(m.get("prefix_hits").is_some(), "{m:?}");
        c.shutdown().unwrap();
    });

    serve_on(listener, coord).unwrap();
    client.join().unwrap();
}

#[test]
fn bad_requests_get_error_lines_not_disconnects() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let coord = scripted_coordinator(2, 1, 0);

    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr).unwrap();
        // malformed JSON
        let r = c.call(Json::Str("not an object".into())).unwrap();
        assert_eq!(r.get("ok").and_then(|x| x.as_bool()), Some(false));
        // unknown op
        let r = c.call(Json::obj().set("op", "frobnicate")).unwrap();
        assert_eq!(r.get("ok").and_then(|x| x.as_bool()), Some(false));
        // generate without a prompt
        let r = c.call(Json::obj().set("op", "generate")).unwrap();
        assert_eq!(r.get("ok").and_then(|x| x.as_bool()), Some(false));
        // oversized max_new rejected by admission, connection still fine
        let r = c
            .call(
                Json::obj()
                    .set("op", "generate")
                    .set("prompt", "hi")
                    .set("max_new", 1usize << 20),
            )
            .unwrap();
        assert_eq!(r.get("ok").and_then(|x| x.as_bool()), Some(false));
        // and a good request still works afterwards
        let r = c.generate("hi", 8, "spec_pv").unwrap();
        assert_eq!(r.get("ok").and_then(|x| x.as_bool()), Some(true), "{r:?}");
        c.shutdown().unwrap();
    });

    serve_on(listener, coord).unwrap();
    client.join().unwrap();
}

#[test]
fn admin_ops_and_deprecated_aliases() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let coord = scripted_coordinator(2, 2, 0);

    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr).unwrap();
        let r = c.generate("warm the scheduler", 8, "spec_pv").unwrap();
        assert_eq!(r.get("ok").and_then(|x| x.as_bool()), Some(true), "{r:?}");

        // versioned admin subcommands answer with v/cmd markers and no
        // deprecation flag
        let m = c.admin("metrics").unwrap();
        assert_eq!(m.get("ok").and_then(|x| x.as_bool()), Some(true), "{m:?}");
        assert_eq!(m.get("v").and_then(|x| x.as_i64()), Some(1), "{m:?}");
        assert_eq!(m.get("cmd").and_then(|x| x.as_str()), Some("metrics"));
        assert!(m.get("deprecated").is_none(), "{m:?}");
        assert_eq!(m.get("completed").and_then(|x| x.as_i64()), Some(1));
        for key in ["kv_pages_resident", "kv_pages_shared", "kv_frag_pct", "swap_faults"] {
            assert!(m.get(key).is_some(), "missing {key}: {m:?}");
        }

        let k = c.admin("kv").unwrap();
        assert_eq!(k.get("ok").and_then(|x| x.as_bool()), Some(true), "{k:?}");
        assert_eq!(k.get("cmd").and_then(|x| x.as_str()), Some("kv"));
        for key in [
            "page_bytes",
            "pages_resident",
            "pages_shared",
            "pages_spilled",
            "ram_bytes",
            "frag_pct",
            "dedup_hits",
            "cow_copies",
            "swap_faults",
            "parked_sessions",
        ] {
            assert!(k.get(key).is_some(), "missing {key}: {k:?}");
        }
        assert_eq!(k.get("parked_sessions").and_then(|x| x.as_i64()), Some(0));

        let s = c.admin("cache").unwrap();
        assert_eq!(s.get("ok").and_then(|x| x.as_bool()), Some(true), "{s:?}");
        assert_eq!(s.get("cmd").and_then(|x| x.as_str()), Some("cache"));
        assert!(s.get("prefix_hits").is_some(), "{s:?}");

        // the old flat op names still answer the same bodies, flagged so
        // clients migrate
        let lm = c.metrics().unwrap();
        assert_eq!(lm.get("ok").and_then(|x| x.as_bool()), Some(true), "{lm:?}");
        assert_eq!(lm.get("deprecated").and_then(|x| x.as_bool()), Some(true));
        assert!(lm.get("v").is_none(), "{lm:?}");
        assert!(lm.get("completed").is_some(), "{lm:?}");
        let lc = c.cache().unwrap();
        assert_eq!(lc.get("deprecated").and_then(|x| x.as_bool()), Some(true));
        assert!(lc.get("prefix_hits").is_some(), "{lc:?}");

        // bad admin requests are error lines, not disconnects
        let e = c.call(Json::obj().set("op", "admin").set("cmd", "frobnicate")).unwrap();
        assert_eq!(e.get("ok").and_then(|x| x.as_bool()), Some(false), "{e:?}");
        let e = c
            .call(Json::obj().set("op", "admin").set("cmd", "metrics").set("v", 2i64))
            .unwrap();
        assert_eq!(e.get("ok").and_then(|x| x.as_bool()), Some(false), "{e:?}");
        let e = c.call(Json::obj().set("op", "admin")).unwrap();
        assert_eq!(e.get("ok").and_then(|x| x.as_bool()), Some(false), "{e:?}");

        // the connection still serves work afterwards
        let r = c.generate("still alive", 8, "spec_pv").unwrap();
        assert_eq!(r.get("ok").and_then(|x| x.as_bool()), Some(true), "{r:?}");
        c.shutdown().unwrap();
    });

    serve_on(listener, coord).unwrap();
    client.join().unwrap();
}
