//! API-compatible **stub** for the `xla-rs` PJRT bindings.
//!
//! The real backend (github.com/LaurentMazare/xla-rs + a PJRT CPU plugin)
//! is a native dependency that is not present in the offline build
//! environment, so this crate provides the exact API surface
//! `specpv::runtime` consumes and fails *at call time* with a clear
//! error. Everything above the runtime — cache accounting, tree
//! construction, the scheduler, the server, the JSON protocol — builds
//! and tests against this stub; artifact-dependent integration tests
//! detect the missing `artifacts/manifest.json` and skip.
//!
//! To run against real hardware, point the `xla` dependency in the
//! workspace `Cargo.toml` at the real bindings (a `[patch]` entry or a
//! path override); no `specpv` source changes are needed.

use std::fmt;

/// Stub error: every device operation reports this.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the real xla-rs backend (this build links the \
         API stub; see rust/xla-stub/src/lib.rs)"
    )))
}

/// Element types the runtime downloads.
pub trait ElementType: Copy {}
impl ElementType for f32 {}
impl ElementType for i32 {}
impl ElementType for u32 {}

/// Parsed HLO module (stub: checks the file exists, keeps the path).
pub struct HloModuleProto {
    #[allow(dead_code)]
    path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(Error(format!("no such HLO file: {path}")));
        }
        Ok(HloModuleProto { path: path.to_string() })
    }
}

/// An XLA computation handle (stub).
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device-resident buffer (stub: holds nothing).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal (stub).
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Anything the runtime can hand to `buffer_from_host_buffer`: slices,
/// Vecs, arrays — taken by reference, so call sites never depend on
/// generic coercion rules.
pub trait HostData {}
impl<T> HostData for [T] {}
impl<T, const N: usize> HostData for [T; N] {}
impl<T> HostData for Vec<T> {}
impl<T: HostData + ?Sized> HostData for &T {}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Real signature: outputs\[replica\]\[buffer\].
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client (stub: constructing it succeeds so `Runtime::new` can
/// load manifests; any compute/transfer call errors).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: HostData + ?Sized>(
        &self,
        _data: &T,
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_descriptive() {
        let c = PjRtClient::cpu().unwrap();
        let err = c
            .buffer_from_host_buffer(&[0f32], &[1], None)
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("xla stub"), "{err}");
    }

    #[test]
    fn missing_hlo_file_rejected() {
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
    }
}
