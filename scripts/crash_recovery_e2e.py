#!/usr/bin/env python3
"""Crash-recovery end-to-end check against a real `specpv serve` process.

Flow (DESIGN.md §17):

  1. start a journaled server (`--journal-dir`, fsync always),
  2. stream a long spec_pv generation and SIGKILL the server mid-stream,
  3. drain the dead socket to EOF (every fully flushed line survives in
     the kernel buffer; a torn tail line is dropped),
  4. snapshot the journal for the CI artifact,
  5. restart the server over the same journal dir and reattach with
     `generate_retry`,
  6. assert the bytes received before the kill plus the replayed suffix
     are **byte-identical** to the final text — zero duplicated, zero
     lost wire lines — and that the recovery counters report the replay.

Stdlib only; exits non-zero on any violation. Artifacts (journal copy,
metrics, summary) land in --out (default: recovery-artifacts/).
"""

import argparse
import json
import os
import shutil
import signal
import socket
import subprocess
import sys
import time

PROMPT_BYTES = 1536
MAX_NEW = 160
KILL_AFTER_DELTAS = 6


def log(msg):
    print(f"[crash-recovery] {msg}", flush=True)


def wait_port(addr, timeout=30.0):
    host, port = addr.split(":")
    deadline = time.time() + timeout
    while time.time() < deadline:
        try:
            with socket.create_connection((host, int(port)), timeout=1.0):
                return
        except OSError:
            time.sleep(0.05)
    raise SystemExit(f"server on {addr} never came up")


class Conn:
    """One newline-delimited-JSON connection."""

    def __init__(self, addr):
        host, port = addr.split(":")
        self.sock = socket.create_connection((host, int(port)), timeout=120.0)
        self.rd = self.sock.makefile("rb")

    def send(self, obj):
        self.sock.sendall((json.dumps(obj) + "\n").encode())

    def recv(self):
        """Next parsed line, or None on EOF / torn tail line."""
        line = self.rd.readline()
        if not line or not line.endswith(b"\n"):
            return None
        try:
            return json.loads(line)
        except json.JSONDecodeError:
            return None

    def call(self, obj):
        self.send(obj)
        r = self.recv()
        if r is None:
            raise SystemExit(f"connection died answering {obj}")
        return r

    def close(self):
        try:
            self.rd.close()
            self.sock.close()
        except OSError:
            pass


def start_server(binary, addr, journal_dir, extra=()):
    cmd = [
        binary,
        "serve",
        "--addr", addr,
        "--backend", "reference",
        "--journal-dir", journal_dir,
        "--journal-fsync", "always",
        "--checkpoint-every-steps", "4",
        "--shards", "1",
        *extra,
    ]
    log(" ".join(cmd))
    proc = subprocess.Popen(cmd)
    wait_port(addr)
    return proc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--binary", default="target/release/specpv")
    ap.add_argument("--addr", default="127.0.0.1:7997")
    ap.add_argument("--out", default="recovery-artifacts")
    ap.add_argument("--journal-dir", default="recovery-journal")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    shutil.rmtree(args.journal_dir, ignore_errors=True)

    prompt = ("The long context under test repeats until it is long enough. " * 64)[
        :PROMPT_BYTES
    ]

    # --- boot 1: stream, SIGKILL mid-stream, drain to EOF -------------
    proc = start_server(args.binary, args.addr, args.journal_dir)
    cl = Conn(args.addr)
    cl.send(
        {
            "op": "generate",
            "prompt": prompt,
            "max_new": MAX_NEW,
            "engine": "spec_pv",
            "stream": True,
        }
    )
    gid = None
    received = []
    deltas = 0
    killed = False
    while True:
        j = cl.recv()
        if j is None:
            break
        if gid is None and "id" in j:
            gid = j["id"]
        if j.get("done"):
            raise SystemExit(
                "generation finished before the SIGKILL — nothing to recover; "
                "raise MAX_NEW"
            )
        if "delta" in j:
            received.append(j["delta"])
            deltas += 1
            if deltas == KILL_AFTER_DELTAS and not killed:
                log(f"SIGKILL after {deltas} deltas (pid {proc.pid})")
                os.kill(proc.pid, signal.SIGKILL)
                killed = True
    cl.close()
    proc.wait()
    if gid is None:
        raise SystemExit("no ack line carried the request id")
    if not killed:
        raise SystemExit(f"stream ended after only {deltas} deltas without a kill")
    recv_text = "".join(received)
    log(f"request {gid}: {deltas} delta lines ({len(recv_text)} bytes) survived the kill")

    # snapshot the journal before the next boot truncates/compacts it
    wal = os.path.join(args.journal_dir, "journal.wal")
    if not os.path.exists(wal):
        raise SystemExit(f"journal file missing at {wal}")
    shutil.copy(wal, os.path.join(args.out, "journal.wal"))
    log(f"journal snapshot: {os.path.getsize(wal)} bytes")

    # --- boot 2: recover and reattach ---------------------------------
    proc = start_server(args.binary, args.addr, args.journal_dir)
    cl = Conn(args.addr)
    cl.send({"op": "generate_retry", "id": gid})
    header = cl.recv()
    if header is None or not header.get("ok") or not header.get("retry"):
        raise SystemExit(f"generate_retry rejected after restart: {header}")
    log(f"retry header: delivered watermark {header.get('delivered')}")
    resumed = []
    fin = None
    while True:
        j = cl.recv()
        if j is None:
            raise SystemExit("connection died mid-replay")
        if j.get("done") or j.get("ok") is False:
            fin = j
            break
        if "delta" in j:
            resumed.append(j["delta"])
    if not fin.get("ok"):
        raise SystemExit(f"resumed request failed: {fin}")
    resumed_text = "".join(resumed)

    # --- byte identity: received + resumed == the whole generation ----
    fin_text = fin.get("text", "")
    joined = recv_text + resumed_text
    if fin.get("tokens") != MAX_NEW:
        raise SystemExit(f"resumed run truncated: tokens={fin.get('tokens')}")
    if joined != fin_text:
        raise SystemExit(
            "byte identity violated across the crash: "
            f"{len(recv_text)} received + {len(resumed_text)} resumed "
            f"!= {len(fin_text)} final bytes"
        )
    log(f"byte-identical: {len(recv_text)} + {len(resumed_text)} == {len(fin_text)} bytes")

    metrics = cl.call({"op": "admin", "cmd": "metrics", "v": 1})
    for key, want in (("recovered_sessions", 1), ("journal_torn_records", 0)):
        if metrics.get(key) != want:
            raise SystemExit(f"metrics[{key}] = {metrics.get(key)}, want {want}: {metrics}")
    if not metrics.get("journal_replayed", 0) >= 2:
        raise SystemExit(f"journal_replayed too low: {metrics}")

    cl.call({"op": "shutdown"})
    cl.close()
    proc.wait(timeout=60)

    with open(os.path.join(args.out, "metrics.json"), "w") as f:
        json.dump(metrics, f, indent=2, sort_keys=True)
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(
            {
                "gid": gid,
                "deltas_before_kill": deltas,
                "received_bytes": len(recv_text),
                "resumed_bytes": len(resumed_text),
                "final_bytes": len(fin_text),
                "delivered_watermark": header.get("delivered"),
                "recovered_sessions": metrics.get("recovered_sessions"),
                "journal_replayed": metrics.get("journal_replayed"),
                "journal_torn_records": metrics.get("journal_torn_records"),
            },
            f,
            indent=2,
            sort_keys=True,
        )
    log("PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
